"""repro.api: RunSpec JSON round-trip + CLI overlay, resume spec
validation, error-feedback sync_state checkpointing (the PR-1 caveat),
and TrainSession parity with the train.py CLI."""
import dataclasses
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (AdamWConfig, CheckpointConfig, DataConfig, MeshSpec,
                       PeriodicCheckpoint, RunSpec, ServeSession, SpecError,
                       SpecMismatchError, SyncConfig, TrainSession)


def tiny_spec(**kw):
    """Smallest useful training scenario (minitron SMOKE, seq 32)."""
    base = dict(arch="minitron_4b", smoke=True, steps=6,
                sync=SyncConfig(mode="optinc", bits=8, block=256),
                optim=AdamWConfig(lr=1e-3),
                data=DataConfig(vocab=0, seq_len=32, global_batch=2, seed=0))
    base.update(kw)
    return RunSpec(**base)


# ------------------------------------------------------------------ spec
def test_runspec_json_roundtrip():
    spec = tiny_spec(
        mesh=MeshSpec(dp=2, tp=1, pods=2, fsdp=True, remat_groups=2),
        sync=SyncConfig(mode="cascade", bits=4, error_layers=(3, 4),
                        error_feedback=True, bucket_bytes=1 << 20),
        ckpt=CheckpointConfig(dir="/tmp/x", every=7, keep=2, resume=True),
        watchdog=2.5, log="m.jsonl", seed=3)
    again = RunSpec.from_json(spec.to_json())
    assert again == spec
    # tuples survive the JSON list round-trip as tuples
    assert again.sync.error_layers == (3, 4)
    assert isinstance(again.sync.axes, tuple)


def test_runspec_rejects_unknown_keys():
    d = RunSpec().to_json_dict()
    d["typo_field"] = 1
    with pytest.raises(SpecError, match="typo_field"):
        RunSpec.from_json_dict(d)
    d2 = RunSpec().to_json_dict()
    d2["mesh"]["pod"] = 2  # should be "pods"
    with pytest.raises(SpecError, match="MeshSpec"):
        RunSpec.from_json_dict(d2)


def test_from_args_overlays_flags(tmp_path):
    spec = RunSpec.from_args(
        ["--arch", "minitron_4b", "--smoke-config", "--sync", "ring",
         "--mesh", "2x1", "--steps", "7", "--seq-len", "48",
         "--global-batch", "4", "--lr", "0.01", "--seed", "5",
         "--error-layers", "3,4", "--bucket-mb", "1"])
    assert (spec.arch, spec.smoke, spec.steps) == ("minitron_4b", True, 7)
    assert (spec.mesh.dp, spec.mesh.tp) == (2, 1)
    assert spec.sync.mode == "ring"
    assert spec.sync.error_layers == (3, 4)
    assert spec.sync.bucket_bytes == 1 << 20
    assert spec.data.seed == 5 and spec.seed == 5
    # cascade auto-provisions its level-2 pod axis
    assert RunSpec.from_args(["--sync", "cascade"]).mesh.pods == 2
    # --spec file is the base; flags override it
    f = tmp_path / "s.json"
    tiny_spec().save(f)
    over = RunSpec.from_args(["--spec", str(f), "--steps", "9"])
    assert over.steps == 9 and over.arch == "minitron_4b" and over.smoke


def test_validate_rejects_bad_specs():
    with pytest.raises(SpecError, match="pod"):
        tiny_spec(sync=SyncConfig(mode="cascade")).validate()
    with pytest.raises(SpecError, match="arch"):
        tiny_spec(arch="no_such_model").validate()
    with pytest.raises(SpecError, match="divisible"):
        tiny_spec(mesh=MeshSpec(dp=4),
                  data=DataConfig(seq_len=32, global_batch=2)).validate()
    with pytest.raises(SpecError, match="resume"):
        tiny_spec(ckpt=CheckpointConfig(resume=True)).validate()


# ------------------------------------------------------- resume validation
def test_resume_with_mismatched_spec_raises(tmp_path):
    spec = tiny_spec(steps=2,
                     ckpt=CheckpointConfig(dir=str(tmp_path), every=1))
    TrainSession(spec, callbacks=[PeriodicCheckpoint(1)]).run()
    bad = dataclasses.replace(
        spec, optim=dataclasses.replace(spec.optim, moment_dtype="bfloat16"),
        ckpt=dataclasses.replace(spec.ckpt, resume=True))
    with pytest.raises(SpecMismatchError, match="moment_dtype"):
        TrainSession(bad, callbacks=[])
    # compatible changes (lr, steps) resume fine
    ok = dataclasses.replace(
        spec, steps=3, optim=dataclasses.replace(spec.optim, lr=5e-4),
        ckpt=dataclasses.replace(spec.ckpt, resume=True))
    sess = TrainSession(ok, callbacks=[])
    assert sess.step == 2


# ------------------------------------------------- sync_state checkpointing
def _ef_spec(direc, **kw):
    return tiny_spec(
        sync=SyncConfig(mode="optinc", bits=8, block=256,
                        error_feedback=True),
        ckpt=CheckpointConfig(dir=str(direc), every=2), **kw)


def test_sync_state_checkpoint_roundtrip(tmp_path):
    spec = _ef_spec(tmp_path, steps=3)
    sess = TrainSession(spec, callbacks=[PeriodicCheckpoint(2)])
    sess.run()
    want = {k: np.asarray(v) for k, v in sess.sync_state.items()}
    # the replicated-leaf residual carries real quantization error (the
    # fsdp group is legitimately empty without --fsdp)
    assert max(np.abs(v).max() for v in want.values() if v.size) > 0
    resumed = TrainSession(
        dataclasses.replace(spec,
                            ckpt=dataclasses.replace(spec.ckpt, resume=True)),
        callbacks=[])
    assert resumed.step == 3
    assert set(resumed.sync_state) == set(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(resumed.sync_state[k]),
                                      want[k])


def test_sparse_residual_checkpoint_roundtrip(tmp_path):
    """SyncConfig.sparse_residuals stores only the residual blocks with a
    nonzero carry (block-sparse sync/ subtree in the checkpoint) and
    restores the dense runtime state bit-exactly; a sparse checkpoint
    also resumes into a session with the flag off (form is detected, not
    assumed)."""
    import json
    import pathlib

    from repro.collectives import (is_packed_residuals, pack_residuals,
                                   unpack_residuals)

    # pure pack/unpack round trip, mostly-zero vector
    rng = np.random.default_rng(0)
    vec = np.zeros((40000,), np.float32)
    vec[12000:12100] = rng.normal(size=100).astype(np.float32)
    packed = pack_residuals({"rep": vec, "fsdp": np.zeros(0, np.float32)})
    assert is_packed_residuals(packed)
    assert packed["rep"]["idx"].shape[0] == 1        # one dirty 4096-block
    restored = unpack_residuals(packed)
    np.testing.assert_array_equal(restored["rep"], vec)
    assert restored["fsdp"].shape == (0,)

    # end-to-end: sparse-checkpointing session -> resume (flag on)
    spec = tiny_spec(
        steps=3,
        sync=SyncConfig(mode="optinc", bits=8, block=256,
                        error_feedback=True, sparse_residuals=True),
        ckpt=CheckpointConfig(dir=str(tmp_path), every=2))
    sess = TrainSession(spec, callbacks=[PeriodicCheckpoint(2)])
    sess.run()
    want = {k: np.asarray(v) for k, v in sess.sync_state.items()}
    assert max(np.abs(v).max() for v in want.values() if v.size) > 0
    man = json.loads((pathlib.Path(tmp_path) / "step_2" /
                      "manifest.json").read_text())
    sync_leaves = [p for p in man["leaves"] if p.startswith("sync/")]
    assert sync_leaves and all(
        p.rsplit("/", 1)[-1] in ("idx", "val", "shape")
        for p in sync_leaves), sync_leaves
    resumed = TrainSession(
        dataclasses.replace(spec,
                            ckpt=dataclasses.replace(spec.ckpt, resume=True)),
        callbacks=[])
    assert resumed.step == 3
    for k in want:
        np.testing.assert_array_equal(np.asarray(resumed.sync_state[k]),
                                      want[k])

    # cross-form: the sparse checkpoint restores with the flag OFF too
    dense_spec = dataclasses.replace(
        spec, sync=dataclasses.replace(spec.sync, sparse_residuals=False),
        ckpt=dataclasses.replace(spec.ckpt, resume=True))
    cross = TrainSession(dense_spec, callbacks=[])
    for k in want:
        np.testing.assert_array_equal(np.asarray(cross.sync_state[k]),
                                      want[k])


def test_error_feedback_resume_matches_uninterrupted(tmp_path):
    """The acceptance regression: a preempted --error-feedback run resumed
    from its checkpoint produces exactly the uninterrupted trajectory."""
    full = TrainSession(_ef_spec(tmp_path / "a", steps=6),
                        callbacks=[PeriodicCheckpoint(2)]).run()
    TrainSession(_ef_spec(tmp_path / "b", steps=4),
                 callbacks=[PeriodicCheckpoint(2)]).run()
    resumed_spec = _ef_spec(tmp_path / "b", steps=6)
    resumed_spec = dataclasses.replace(
        resumed_spec, ckpt=dataclasses.replace(resumed_spec.ckpt, resume=True))
    resumed = TrainSession(resumed_spec,
                           callbacks=[PeriodicCheckpoint(2)]).run()
    f = {r["step"]: r["loss"] for r in full}
    g = {r["step"]: r["loss"] for r in resumed}
    assert min(g) == 4  # really resumed, not restarted
    for s in (4, 5):
        assert f[s] == g[s], (s, f[s], g[s])


# --------------------------------------------------- session/CLI parity
_PROGRAMMATIC = """
import json
from repro.api import (AdamWConfig, DataConfig, RunSpec, SyncConfig,
                       TrainSession)
spec = RunSpec(arch="minitron_4b", smoke=True, steps=3,
               sync=SyncConfig(mode="optinc", bits=8),
               optim=AdamWConfig(lr=1e-3),
               data=DataConfig(vocab=0, seq_len=32, global_batch=2, seed=0))
hist = TrainSession(spec, callbacks=[]).run()
print("HIST " + json.dumps(hist))
"""


@pytest.mark.slow
def test_train_session_matches_cli_trajectory():
    """launch/train.py (argparse -> RunSpec -> TrainSession) reproduces the
    programmatic TrainSession losses exactly (both in fresh processes —
    in-process jit caches can change bf16 fusion and wiggle the last
    digit)."""
    from conftest import subprocess_env
    args = ["--arch", "minitron_4b", "--smoke-config", "--sync", "optinc",
            "--steps", "3", "--global-batch", "2", "--seq-len", "32",
            "--lr", "1e-3", "--seed", "0", "--bits", "8"]
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=900, env=subprocess_env())
    assert r.returncode == 0, r.stderr[-3000:]
    cli = {rec["step"]: rec["loss"]
           for rec in (json.loads(l) for l in r.stdout.splitlines()
                       if l.startswith("{"))}
    p = subprocess.run([sys.executable, "-c", _PROGRAMMATIC],
                       capture_output=True, text=True, timeout=900,
                       env=subprocess_env())
    assert p.returncode == 0, p.stderr[-3000:]
    line = [l for l in p.stdout.splitlines() if l.startswith("HIST ")][0]
    hist = json.loads(line[len("HIST "):])
    assert len(hist) == 3
    for rec in hist:
        assert cli[rec["step"]] == rec["loss"], (rec, cli)


# ------------------------------------------------------------- serving
def test_serve_session_generates(tmp_path):
    spec = tiny_spec(steps=1)
    serve = ServeSession(spec)
    prompts = np.zeros((2, 4), np.int32)
    logits, _ = serve.prefill(prompts)
    assert np.isfinite(np.asarray(logits)).all()
    gen = serve.generate(prompts, gen_len=5, max_seq=16)
    assert gen.shape == (2, 5)
    assert (np.asarray(gen) >= 0).all()
    assert (np.asarray(gen) < serve.cfg.vocab).all()
