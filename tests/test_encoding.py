"""PAM4 encoding / quantization / preprocessing unit (deterministic tests;
the hypothesis property tests live in test_photonics_properties.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.photonics import encoding as enc


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_pam4_roundtrip_exhaustive_or_sampled(bits):
    n = 2 ** bits
    vals = (jnp.arange(0, n - 1, dtype=jnp.int32) if bits <= 8 else
            jnp.asarray(np.random.default_rng(0).integers(0, n - 1, 4096)))
    sym = enc.pam4_encode(vals, bits)
    assert sym.shape[-1] == enc.num_symbols(bits)
    assert int(sym.max()) <= 3 and int(sym.min()) >= 0
    assert (enc.pam4_decode(sym) == vals).all()


def test_quantize_idempotent():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    spec = enc.QuantSpec(bits=8, block=64)
    u, s = enc.quantize(g, spec)
    gd = enc.dequantize(u, s, spec)
    u2, _ = enc.quantize(gd, spec, scale=s)
    assert (u == u2).all()


def test_qmean_matches_eq3():
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.integers(0, 254, (8, 1000)))
    got = enc.qmean(u)
    want = np.round(np.asarray(u, np.float64).sum(0) / 8).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("bits,k,n", [(8, 4, 4), (8, 4, 8), (16, 4, 4),
                                      (8, 2, 4), (6, 3, 2)])
def test_preprocess_oracle_equals_expected(bits, k, n):
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.integers(0, 2 ** bits - 1, (n, 500)))
    sym = enc.pam4_encode(u, bits)
    a = enc.preprocess(sym, bits, k)
    assert a.shape[-1] == k
    g = enc.preprocess_group_size(bits, k)
    assert float(a.max()) <= 4 ** g - 1
    out = enc.oracle_from_preprocessed(a, bits, k)
    want = enc.expected_avg_symbols(sym, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_splitter_broadcasts():
    sym = jnp.asarray([[1, 2, 3]])
    out = enc.splitter(sym, 5)
    assert out.shape == (5, 1, 3)
    assert (out == sym[None]).all()
