"""repro.serving end-to-end: ServeConfig threading through RunSpec,
engine-vs-session greedy parity under staggered arrivals, preemption
resume, checkpoint hot-swap, and the prefill-seeded generate path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (CheckpointConfig, RunSpec, ServeConfig, ServeSession,
                       SpecError)
from repro.checkpoint.ckpt import save_checkpoint
from repro.models import lm
from repro.serving.engine import ServeEngine

from test_api import tiny_spec


def serve_spec(**serve_kw):
    kw = dict(page_size=4, max_active=8, max_seq=32, max_queue=32)
    kw.update(serve_kw)
    return dataclasses.replace(tiny_spec(), serve=ServeConfig(**kw))


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, (int(rng.integers(3, 11)),)).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------- config
def test_serve_config_roundtrips_through_runspec():
    spec = serve_spec(temperature=0.7, top_k=5, reload_every=3,
                      stop_token=2)
    spec = dataclasses.replace(
        spec, ckpt=CheckpointConfig(dir="/tmp/x"))
    again = RunSpec.from_json(spec.to_json())
    assert again == spec and again.serve.top_k == 5
    assert again.serve.max_blocks == 8 and again.serve.capacity == 32


def test_serve_cli_overlay():
    spec = RunSpec.from_args(
        ["--arch", "minitron_4b", "--smoke-config", "--page-size", "8",
         "--max-active", "4", "--max-seq", "64", "--temperature", "0.5",
         "--top-k", "3", "--serve-pages", "9", "--max-new-tokens", "12"])
    s = spec.serve
    assert (s.page_size, s.max_active, s.max_seq) == (8, 4, 64)
    assert (s.temperature, s.top_k, s.pages, s.max_new_tokens) \
        == (0.5, 3, 9, 12)


def test_serve_config_validation():
    with pytest.raises(SpecError, match="max_seq"):
        serve_spec(max_seq=2, page_size=4).validate()
    with pytest.raises(SpecError, match="top-k"):
        serve_spec(top_k=3, temperature=0.0).validate()
    with pytest.raises(SpecError, match="reload-every"):
        serve_spec(reload_every=2).validate()
    with pytest.raises(ValueError, match="page_size"):
        ServeConfig(page_size=0)
    with pytest.raises(ValueError, match="temperature"):
        ServeConfig(temperature=-1.0)


def test_engine_rejects_unpaged_and_dp_meshes():
    from repro.api import MeshSpec
    with pytest.raises(NotImplementedError, match="1xTP"):
        ServeEngine(dataclasses.replace(
            serve_spec(), mesh=MeshSpec(dp=2),
            data=dataclasses.replace(tiny_spec().data, global_batch=4)))


# ------------------------------------------------- engine/session parity
def test_engine_matches_session_under_staggered_load():
    """>= 8 concurrent sequences, staggered arrival and completion: every
    request's greedy tokens equal the single-sequence ServeSession path
    bit for bit (prefill==decode parity + null-page masking)."""
    spec = serve_spec()
    sess = ServeSession(spec)
    eng = sess.engine()
    prompts = _prompts(10, sess.cfg.vocab)
    budgets = [4 + (i % 5) * 2 for i in range(10)]

    # staggered arrival: half up front, the rest one per step
    rids = [eng.submit(p, b) for p, b in zip(prompts[:5], budgets[:5])]
    pending = list(zip(prompts[5:], budgets[5:]))
    while eng.has_work() or pending:
        if pending:
            p, b = pending.pop(0)
            rids.append(eng.submit(p, b))
        eng.step()
    assert eng.max_observed_active == 8, eng.max_observed_active
    assert sorted(eng.results) == sorted(rids)
    for rid, p, b in zip(rids, prompts, budgets):
        ref = np.asarray(sess.generate(np.asarray([p]), gen_len=b,
                                       max_seq=32))[0]
        got = np.asarray(eng.results[rid])
        np.testing.assert_array_equal(got, ref, err_msg=f"rid {rid}")


def test_engine_preemption_resumes_exactly():
    """A pool too small for every admitted sequence forces preemption;
    evicted requests re-prefill (prompt + generated so far) and still
    finish with the exact greedy continuation."""
    spec = serve_spec(max_active=4, pages=9)  # 8 usable pages, 4 slots
    sess = ServeSession(spec)
    eng = sess.engine()
    prompts = _prompts(4, sess.cfg.vocab, seed=1)
    rids = [eng.submit(p, 8) for p in prompts]
    while eng.has_work():
        eng.step()
    assert eng.sched.n_preempted > 0
    for rid, p in zip(rids, prompts):
        ref = np.asarray(sess.generate(np.asarray([p]), gen_len=8,
                                       max_seq=32))[0]
        np.testing.assert_array_equal(np.asarray(eng.results[rid]), ref)


def test_engine_stop_token_and_sampling():
    spec = serve_spec(temperature=0.8, top_k=4)
    eng = ServeEngine(spec)
    out = eng.serve(_prompts(3, eng.cfg.vocab), max_new_tokens=6)
    assert all(len(v) == 6 for v in out.values())
    assert all((np.asarray(v) < eng.cfg.vocab).all() for v in out.values())
    # stop token ends a sequence before its budget
    spec2 = serve_spec(stop_token=0)
    eng2 = ServeEngine(spec2, params=eng.params)
    out2 = eng2.serve(_prompts(3, eng2.cfg.vocab), max_new_tokens=12)
    for v in out2.values():
        v = list(v)
        assert 0 not in v[:-1] and len(v) <= 12


# ------------------------------------------------------------- hot-swap
def test_hot_swap_picks_up_newer_checkpoint_mid_serve(tmp_path):
    spec = dataclasses.replace(
        serve_spec(reload_every=1),
        ckpt=CheckpointConfig(dir=str(tmp_path), resume=True))
    cfg = spec.model_config()
    ctx = spec.mesh.ctx()
    p0 = lm.init_params(cfg, ctx, jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, p0)

    eng = ServeEngine(spec)
    assert eng.params_step == 1
    prompts = _prompts(2, cfg.vocab, seed=2)
    rid0 = eng.submit(prompts[0], 10)
    for _ in range(3):
        eng.step()
    # a concurrent trainer writes a newer checkpoint mid-serve
    p1 = jax.tree.map(lambda a: a * 1.5, p0)
    save_checkpoint(tmp_path, 7, p1)
    rid1 = eng.submit(prompts[1], 6)
    while eng.has_work():
        eng.step()
    assert eng.params_step == 7          # swapped without a restart
    assert len(eng.results[rid0]) == 10 and len(eng.results[rid1]) == 6
    # a request admitted after the swap decodes with the NEW params
    sess_new = ServeSession(spec, params=p1)
    ref = np.asarray(sess_new.generate(np.asarray([prompts[1]]), gen_len=6,
                                       max_seq=32))[0]
    np.testing.assert_array_equal(np.asarray(eng.results[rid1]), ref)


# ------------------------------------- prefill-seeded generate (session)
def test_generate_prefill_path_matches_replay():
    """ServeSession.generate's compiled-prefill path is bit-exact with the
    token-by-token decode replay it replaced (greedy)."""
    sess = ServeSession(tiny_spec())
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, sess.cfg.vocab, (3, 7))
    fast = np.asarray(sess.generate(prompts, gen_len=6, max_seq=24))
    slow = np.asarray(sess._generate_replay(prompts, gen_len=6, max_seq=24))
    np.testing.assert_array_equal(fast, slow)
