"""repro.serving end-to-end: ServeConfig threading through RunSpec,
engine-vs-session greedy parity under staggered arrivals (both decode
backends), preemption resume, checkpoint hot-swap, dp>1 serving, and
the prefill-seeded generate path."""
import dataclasses
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import (CheckpointConfig, RunSpec, ServeConfig, ServeSession,
                       SpecError)
from repro.checkpoint.ckpt import save_checkpoint
from repro.models import lm
from repro.serving.engine import ServeEngine

from test_api import tiny_spec


def serve_spec(**serve_kw):
    kw = dict(page_size=4, max_active=8, max_seq=32, max_queue=32)
    kw.update(serve_kw)
    return dataclasses.replace(tiny_spec(), serve=ServeConfig(**kw))


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, (int(rng.integers(3, 11)),)).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------- config
def test_serve_config_roundtrips_through_runspec():
    spec = serve_spec(temperature=0.7, top_k=5, reload_every=3,
                      stop_token=2)
    spec = dataclasses.replace(
        spec, ckpt=CheckpointConfig(dir="/tmp/x"))
    again = RunSpec.from_json(spec.to_json())
    assert again == spec and again.serve.top_k == 5
    assert again.serve.max_blocks == 8 and again.serve.capacity == 32


def test_serve_cli_overlay():
    spec = RunSpec.from_args(
        ["--arch", "minitron_4b", "--smoke-config", "--page-size", "8",
         "--max-active", "4", "--max-seq", "64", "--temperature", "0.5",
         "--top-k", "3", "--serve-pages", "9", "--max-new-tokens", "12",
         "--decode-backend", "paged", "--kv-dtype", "bf16"])
    s = spec.serve
    assert (s.page_size, s.max_active, s.max_seq) == (8, 4, 64)
    assert (s.temperature, s.top_k, s.pages, s.max_new_tokens) \
        == (0.5, 3, 9, 12)
    assert (s.decode_backend, s.kv_dtype) == ("paged", "bf16")


def test_serve_config_validation():
    with pytest.raises(SpecError, match="max_seq"):
        serve_spec(max_seq=2, page_size=4).validate()
    with pytest.raises(SpecError, match="top-k"):
        serve_spec(top_k=3, temperature=0.0).validate()
    with pytest.raises(SpecError, match="reload-every"):
        serve_spec(reload_every=2).validate()
    with pytest.raises(ValueError, match="page_size"):
        ServeConfig(page_size=0)
    with pytest.raises(ValueError, match="temperature"):
        ServeConfig(temperature=-1.0)
    with pytest.raises(ValueError, match="decode_backend"):
        ServeConfig(decode_backend="contiguous")
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeConfig(kv_dtype="fp8")


def test_engine_rejects_unpaged_arch():
    # ssm/enc-dec/moe caches have no paged layout; they serve through
    # ServeSession (dp>1 dense meshes are legal now — batched prefill
    # shards its rows, decode runs replicated)
    with pytest.raises(NotImplementedError, match="ServeSession"):
        ServeEngine(dataclasses.replace(serve_spec(), arch="xlstm_125m"))


# ------------------------------------------------- engine/session parity
@pytest.mark.parametrize("backend", ["gather", "paged"])
def test_engine_matches_session_under_staggered_load(backend):
    """>= 8 concurrent sequences, staggered arrival and completion: every
    request's greedy tokens equal the single-sequence ServeSession path
    bit for bit (prefill==decode parity + null-page masking).  Runs under
    BOTH decode backends — off-TPU 'paged' dispatches to the gather math,
    so the equality stays bitwise."""
    spec = serve_spec(decode_backend=backend)
    sess = ServeSession(spec)
    eng = sess.engine()
    prompts = _prompts(10, sess.cfg.vocab)
    budgets = [4 + (i % 5) * 2 for i in range(10)]

    # staggered arrival: half up front, the rest one per step
    rids = [eng.submit(p, b) for p, b in zip(prompts[:5], budgets[:5])]
    pending = list(zip(prompts[5:], budgets[5:]))
    while eng.has_work() or pending:
        if pending:
            p, b = pending.pop(0)
            rids.append(eng.submit(p, b))
        eng.step()
    assert eng.max_observed_active == 8, eng.max_observed_active
    assert sorted(eng.results) == sorted(rids)
    for rid, p, b in zip(rids, prompts, budgets):
        ref = np.asarray(sess.generate(np.asarray([p]), gen_len=b,
                                       max_seq=32))[0]
        got = np.asarray(eng.results[rid])
        np.testing.assert_array_equal(got, ref, err_msg=f"rid {rid}")


def test_engine_preemption_resumes_exactly():
    """A pool too small for every admitted sequence forces preemption;
    evicted requests re-prefill (prompt + generated so far) and still
    finish with the exact greedy continuation."""
    spec = serve_spec(max_active=4, pages=9)  # 8 usable pages, 4 slots
    sess = ServeSession(spec)
    eng = sess.engine()
    prompts = _prompts(4, sess.cfg.vocab, seed=1)
    rids = [eng.submit(p, 8) for p in prompts]
    while eng.has_work():
        eng.step()
    assert eng.sched.n_preempted > 0
    for rid, p in zip(rids, prompts):
        ref = np.asarray(sess.generate(np.asarray([p]), gen_len=8,
                                       max_seq=32))[0]
        np.testing.assert_array_equal(np.asarray(eng.results[rid]), ref)


def test_engine_paged_kernel_interpreted_matches_gather():
    """FORCE_KERNEL routes the 'paged' backend through the interpreted
    Pallas kernel on CPU; the greedy tokens still match the gather
    engine (online softmax agrees to ~1e-7, far inside the argmax
    margin on these logits)."""
    from repro.kernels import paged_attention as pk
    eng_g = ServeEngine(serve_spec(decode_backend="gather"))
    prompts = _prompts(4, eng_g.cfg.vocab, seed=4)
    ref = eng_g.serve(prompts, max_new_tokens=6)
    pk.FORCE_KERNEL = True
    try:
        eng_p = ServeEngine(serve_spec(decode_backend="paged"),
                            params=eng_g.params)
        got = eng_p.serve(prompts, max_new_tokens=6)
    finally:
        pk.FORCE_KERNEL = None
    assert sorted(got) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(np.asarray(got[rid]),
                                      np.asarray(ref[rid]))


def test_engine_kv_dtype_f32_pool():
    """kv_dtype='f32' upcasts the pool (model KV is bf16 -> exact) and
    the engine still serves full budgets; 'auto' follows the model."""
    import jax.numpy as jnp
    eng = ServeEngine(serve_spec(kv_dtype="f32"))
    assert eng.pool["layers"]["k"].dtype == jnp.float32
    out = eng.serve(_prompts(3, eng.cfg.vocab, seed=5), max_new_tokens=5)
    assert all(len(v) == 5 for v in out.values())
    eng_auto = ServeEngine(serve_spec(), params=eng.params)
    assert eng_auto.pool["layers"]["k"].dtype == jnp.bfloat16


_DP2_PROG = """\
import dataclasses
import numpy as np
from repro.api import (AdamWConfig, DataConfig, MeshSpec, RunSpec,
                       ServeConfig, SyncConfig)
from repro.serving.engine import ServeEngine

def spec(dp, gb):
    return RunSpec(arch="minitron_4b", smoke=True, steps=6,
                   sync=SyncConfig(mode="optinc", bits=8, block=256),
                   optim=AdamWConfig(lr=1e-3),
                   data=DataConfig(vocab=0, seq_len=32, global_batch=gb,
                                   seed=0),
                   mesh=MeshSpec(dp=dp),
                   serve=ServeConfig(page_size=4, max_active=8, max_seq=32,
                                     max_queue=32))

rng = np.random.default_rng(0)
prompts = [rng.integers(0, 128, (int(rng.integers(3, 11)),)).tolist()
           for _ in range(6)]
e1 = ServeEngine(spec(1, 2))
out1 = e1.serve(prompts, max_new_tokens=6)
e2 = ServeEngine(spec(2, 4), params=e1.params)
out2 = e2.serve(prompts, max_new_tokens=6)
assert sorted(out1) == sorted(out2)
for rid in out1:
    np.testing.assert_array_equal(out1[rid], out2[rid])
print("DP_OK")
"""


@pytest.mark.slow
def test_engine_dp2_matches_dp1():
    """dp=2 serving meshes are legal now: batched prefill shards its
    rows over 'data', decode runs replicated, and the served tokens are
    bit-equal to the dp=1 engine (same process, same params)."""
    from conftest import subprocess_env
    r = subprocess.run(
        [sys.executable, "-c", _DP2_PROG],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(
            XLA_FLAGS="--xla_force_host_platform_device_count=2"))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DP_OK" in r.stdout


def test_engine_stop_token_and_sampling():
    spec = serve_spec(temperature=0.8, top_k=4)
    eng = ServeEngine(spec)
    out = eng.serve(_prompts(3, eng.cfg.vocab), max_new_tokens=6)
    assert all(len(v) == 6 for v in out.values())
    assert all((np.asarray(v) < eng.cfg.vocab).all() for v in out.values())
    # stop token ends a sequence before its budget
    spec2 = serve_spec(stop_token=0)
    eng2 = ServeEngine(spec2, params=eng.params)
    out2 = eng2.serve(_prompts(3, eng2.cfg.vocab), max_new_tokens=12)
    for v in out2.values():
        v = list(v)
        assert 0 not in v[:-1] and len(v) <= 12


# ------------------------------------------------------------- hot-swap
def test_hot_swap_picks_up_newer_checkpoint_mid_serve(tmp_path):
    spec = dataclasses.replace(
        serve_spec(reload_every=1),
        ckpt=CheckpointConfig(dir=str(tmp_path), resume=True))
    cfg = spec.model_config()
    ctx = spec.mesh.ctx()
    p0 = lm.init_params(cfg, ctx, jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, p0)

    eng = ServeEngine(spec)
    assert eng.params_step == 1
    prompts = _prompts(2, cfg.vocab, seed=2)
    rid0 = eng.submit(prompts[0], 10)
    for _ in range(3):
        eng.step()
    # a concurrent trainer writes a newer checkpoint mid-serve
    p1 = jax.tree.map(lambda a: a * 1.5, p0)
    save_checkpoint(tmp_path, 7, p1)
    rid1 = eng.submit(prompts[1], 6)
    while eng.has_work():
        eng.step()
    assert eng.params_step == 7          # swapped without a restart
    assert len(eng.results[rid0]) == 10 and len(eng.results[rid1]) == 6
    # a request admitted after the swap decodes with the NEW params
    sess_new = ServeSession(spec, params=p1)
    ref = np.asarray(sess_new.generate(np.asarray([prompts[1]]), gen_len=6,
                                       max_seq=32))[0]
    np.testing.assert_array_equal(np.asarray(eng.results[rid1]), ref)


def test_reloader_stat_guard_skips_idle_listings(tmp_path, monkeypatch):
    """Idle polls cost one os.stat: the directory listing / manifest
    parse (latest_step) only runs when the checkpoint dir's mtime moved.
    A checkpoint landing after the guard armed is still picked up."""
    from repro.serving import reload as reload_mod
    spec = dataclasses.replace(
        serve_spec(reload_every=1),
        ckpt=CheckpointConfig(dir=str(tmp_path), resume=True))
    cfg = spec.model_config()
    p0 = lm.init_params(cfg, spec.mesh.ctx(), jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, p0)

    mesh = spec.mesh.build()
    r = reload_mod.ParamReloader(spec, cfg, mesh)
    calls = {"n": 0}
    real = reload_mod.latest_step

    def counting(d):
        calls["n"] += 1
        return real(d)

    monkeypatch.setattr(reload_mod, "latest_step", counting)
    got = r.poll()
    assert got is not None and got[1] == 1
    n_loaded = calls["n"]
    for _ in range(5):
        assert r.poll() is None          # idle: stat short-circuits
    assert calls["n"] == n_loaded        # no listings while idle
    save_checkpoint(tmp_path, 3, p0)     # dir mtime moves
    got = r.poll()
    assert got is not None and got[1] == 3
    assert calls["n"] == n_loaded + 1


# ------------------------------------- prefill-seeded generate (session)
def test_generate_prefill_path_matches_replay():
    """ServeSession.generate's compiled-prefill path is bit-exact with the
    token-by-token decode replay it replaced (greedy)."""
    sess = ServeSession(tiny_spec())
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, sess.cfg.vocab, (3, 7))
    fast = np.asarray(sess.generate(prompts, gen_len=6, max_seq=24))
    slow = np.asarray(sess._generate_replay(prompts, gen_len=6, max_seq=24))
    np.testing.assert_array_equal(fast, slow)
