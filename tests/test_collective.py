"""Gradient-sync collectives: run on 8 host devices in a subprocess (the
main pytest process must keep the default single-device config)."""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.collectives import SyncConfig, sync_gradients
    from repro.core.collective import ring_allreduce
    from repro.photonics.encoding import QuantSpec, quantize, dequantize, qmean
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g = rng.normal(size=(8, 4096)).astype(np.float32)

    def run(mode, **kw):
        sync = SyncConfig(mode=mode, axes=("data",), **kw)
        def f(x):
            out, _ = sync_gradients([x], sync, None, None)
            return out[0]
        fn = jax.shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                           check_vma=False)
        return np.asarray(jax.jit(fn)(jnp.asarray(g.reshape(-1))))

    mean = g.mean(0)
    out = {}
    # ring == psum == exact mean
    ring = run("ring").reshape(8, 4096)
    psum = run("psum").reshape(8, 4096)
    out["ring_psum_max_diff"] = float(np.abs(ring - psum).max())
    out["ring_exact_max_diff"] = float(np.abs(ring - mean[None]).max())
    out["ring_identical_across_devices"] = float(np.abs(ring - ring[0]).max())

    # optinc == Q(mean) in the integer domain (eq. 3)
    opt = run("optinc", bits=8, block=512).reshape(8, 4096)
    out["optinc_identical"] = float(np.abs(opt - opt[0]).max())
    spec = QuantSpec(bits=8, block=512)
    scale = np.abs(g).max(0).reshape(8, 512).max(1)  # global scale over peers
    scale = np.abs(g.reshape(8, 8, 512)).max(axis=(0, 2))
    us = []
    for n in range(8):
        u, _ = quantize(jnp.asarray(g[n]), spec, scale=jnp.asarray(scale))
        us.append(np.asarray(u))
    u_avg = qmean(jnp.asarray(np.stack(us)))
    want = np.asarray(dequantize(u_avg, jnp.asarray(scale), spec))
    out["optinc_matches_eq3"] = float(np.abs(opt[0] - want).max())
    print(json.dumps(out))
""")


def test_collectives_multidevice():
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600, env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ring_psum_max_diff"] < 1e-5
    assert out["ring_exact_max_diff"] < 1e-5
    assert out["ring_identical_across_devices"] == 0.0
    assert out["optinc_identical"] == 0.0
    assert out["optinc_matches_eq3"] < 1e-6
