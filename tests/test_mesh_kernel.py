"""Fused Pallas mesh kernel (kernels.mesh_scan / mesh_backend='pallas'):
parity against the XLA scan and the numpy oracle, the fused epilogue,
interpret auto-detection, and the RunSpec threading of the knob."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mesh_scan import mesh_scan
from repro.photonics import MZIMesh, ONNModule, encoding, mesh, mzi


def _random_mesh(m, seed):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    return q, MZIMesh.compile(mzi.givens_decompose(q)), rng


# --------------------- kernel vs xla scan vs numpy oracle -------------------

@pytest.mark.parametrize("m", [2, 5, 16, 64, 130])
@pytest.mark.parametrize("transpose", [False, True])
def test_mesh_scan_matches_xla_and_oracle(m, transpose):
    q, emu, rng = _random_mesh(m, m)
    x = rng.normal(size=(7, m)).astype(np.float32)
    want_np = x @ (q if transpose else q.T)
    got_xla = emu.apply(jnp.asarray(x), transpose=transpose)
    got_pl = emu.apply(jnp.asarray(x), transpose=transpose,
                       backend="pallas")
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(got_xla),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_pl), want_np, atol=1e-4)


@pytest.mark.parametrize("batch_shape", [(), (1,), (9,), (2, 3), (4, 1, 2)])
def test_mesh_scan_batch_shapes(batch_shape):
    _, emu, rng = _random_mesh(12, 0)
    x = jnp.asarray(rng.normal(size=batch_shape + (12,)).astype(np.float32))
    got = emu.apply(x, backend="pallas")
    want = emu.apply(x)
    assert got.shape == want.shape == batch_shape + (12,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_mesh_scan_fused_epilogue():
    """post_scale is the in-kernel diagonal epilogue: y * d, fused."""
    _, emu, rng = _random_mesh(16, 1)
    x = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float32))
    d = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    got = mesh_scan(emu.signs, emu.perm, emu.ca, emu.sa, x, post_scale=d)
    want = emu.apply(x) * d
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_mesh_scan_under_jit_and_vmap():
    _, emu, rng = _random_mesh(24, 2)
    x = jnp.asarray(rng.normal(size=(6, 24)).astype(np.float32))
    want = np.asarray(emu.apply(x))
    jat = jax.jit(lambda v: emu.apply(v, backend="pallas"))(x)
    vm = jax.vmap(lambda v: emu.apply(v, backend="pallas"))(x)
    np.testing.assert_allclose(np.asarray(jat), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vm), want, atol=1e-5)


def test_unknown_backend_rejected():
    _, emu, rng = _random_mesh(4, 3)
    x = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="mesh backend"):
        emu.apply(x, backend="bogus")


# ------------------- full ONN pipeline, x64 acceptance bar ------------------

PALLAS_ORACLE_X64 = textwrap.dedent("""
    import json
    import jax, numpy as np, jax.numpy as jnp
    from repro.photonics import mesh, onn
    from repro.photonics.onn import ONNConfig

    CFGS = [
        ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                  bits=4, n_servers=2, k_inputs=2),
        ONNConfig(structure=(4, 32, 64, 32, 4), approx_layers=(),
                  bits=8, n_servers=4, k_inputs=4),
        ONNConfig(structure=(1, 4, 1), approx_layers=(), bits=2,
                  n_servers=3, k_inputs=1),
    ]
    diffs = []
    for i, cfg in enumerate(CFGS):
        params = onn.project_approx(
            onn.init_params(cfg, jax.random.PRNGKey(i)), cfg)
        hw = onn.map_to_hardware(params, cfg)
        progs = mesh.compile_hardware(hw)          # float64 under x64
        a = np.random.default_rng(i).uniform(
            0, cfg.in_scale, size=(32, cfg.structure[0]))
        want = onn.apply_hardware(hw, a, cfg)
        got = np.asarray(jax.jit(lambda x: mesh.apply_hardware(
            progs, x, cfg, backend="pallas"))(jnp.asarray(a)))
        diffs.append(float(np.abs(got - want).max()))
    print(json.dumps(diffs))
""")


def test_pallas_oracle_parity_1e6_x64():
    """Acceptance bar: the fused kernel (interpret mode on CPU) matches
    the numpy apply_hardware oracle to <= 1e-6 on every ONNConfig
    structure the suite uses, under x64."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", PALLAS_ORACLE_X64],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(JAX_ENABLE_X64="1"))
    assert r.returncode == 0, r.stderr[-2000:]
    diffs = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(d <= 1e-6 for d in diffs), diffs


# ----------------------- module / fidelity plumbing -------------------------

def test_exact_identity_symbols_pallas_backend():
    """ONNModule.symbols(fidelity='mesh', mesh_backend='pallas') keeps the
    exact-identity transfer function exact (all 27 3-server codes)."""
    module = ONNModule.exact_identity(bits=2, n_servers=3)
    codes = np.stack(np.meshgrid(*([np.arange(3)] * 3),
                                 indexing="ij")).reshape(3, -1)
    sym = encoding.pam4_encode(jnp.asarray(codes), 2)
    a = encoding.preprocess(sym, 2, module.cfg.k_inputs)
    want = np.asarray(encoding.expected_avg_symbols(sym, 2))
    got = np.asarray(module.symbols(a, fidelity="mesh",
                                    mesh_backend="pallas"))
    np.testing.assert_array_equal(got, want)


def test_mesh_scan_interpret_auto_agrees():
    """Auto-detected interpret and forced interpret=True must agree (on
    TPU this pits the compiled kernel against the interpreter)."""
    _, emu, rng = _random_mesh(16, 4)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    auto = mesh_scan(emu.signs, emu.perm, emu.ca, emu.sa, x)
    forced = mesh_scan(emu.signs, emu.perm, emu.ca, emu.sa, x,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))


# --------------------------- RunSpec threading ------------------------------

def test_runspec_mesh_backend_flag_and_roundtrip():
    from repro.api import RunSpec, SpecError
    spec = RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                              "--fidelity", "mesh",
                              "--mesh-backend", "pallas"])
    assert spec.sync.photonics.mesh_backend == "pallas"
    assert RunSpec.from_json(spec.to_json()) == spec
    # the knob only applies to the mesh fidelity
    with pytest.raises(SpecError, match="mesh-backend"):
        RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                           "--mesh-backend", "pallas"])
    # a bad value in a --spec file is a SpecError, not a raw ValueError
    with pytest.raises(SpecError, match="invalid PhotonicsConfig"):
        RunSpec.from_json_dict(
            {"sync": {"photonics": {"mesh_backend": "bogus"}}})
