"""Fused Pallas mesh kernel (kernels.mesh_scan / mesh_backend='pallas'):
parity against the XLA scan and the numpy oracle, the fused epilogue,
interpret auto-detection, and the RunSpec threading of the knob."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mesh_scan import mesh_scan
from repro.photonics import MZIMesh, ONNModule, encoding, mesh, mzi


def _random_mesh(m, seed):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    return q, MZIMesh.compile(mzi.givens_decompose(q)), rng


# --------------------- kernel vs xla scan vs numpy oracle -------------------

@pytest.mark.parametrize("m", [2, 5, 16, 64, 130])
@pytest.mark.parametrize("transpose", [False, True])
def test_mesh_scan_matches_xla_and_oracle(m, transpose):
    q, emu, rng = _random_mesh(m, m)
    x = rng.normal(size=(7, m)).astype(np.float32)
    want_np = x @ (q if transpose else q.T)
    got_xla = emu.apply(jnp.asarray(x), transpose=transpose)
    got_pl = emu.apply(jnp.asarray(x), transpose=transpose,
                       backend="pallas")
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(got_xla),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_pl), want_np, atol=1e-4)


@pytest.mark.parametrize("batch_shape", [(), (1,), (9,), (2, 3), (4, 1, 2)])
def test_mesh_scan_batch_shapes(batch_shape):
    _, emu, rng = _random_mesh(12, 0)
    x = jnp.asarray(rng.normal(size=batch_shape + (12,)).astype(np.float32))
    got = emu.apply(x, backend="pallas")
    want = emu.apply(x)
    assert got.shape == want.shape == batch_shape + (12,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_mesh_scan_fused_epilogue():
    """post_scale is the in-kernel diagonal epilogue: y * d, fused."""
    _, emu, rng = _random_mesh(16, 1)
    x = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float32))
    d = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    got = mesh_scan(emu.signs, emu.perm, emu.ca, emu.sa, x, post_scale=d)
    want = emu.apply(x) * d
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_mesh_scan_under_jit_and_vmap():
    _, emu, rng = _random_mesh(24, 2)
    x = jnp.asarray(rng.normal(size=(6, 24)).astype(np.float32))
    want = np.asarray(emu.apply(x))
    jat = jax.jit(lambda v: emu.apply(v, backend="pallas"))(x)
    vm = jax.vmap(lambda v: emu.apply(v, backend="pallas"))(x)
    np.testing.assert_allclose(np.asarray(jat), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vm), want, atol=1e-5)


def test_unknown_backend_rejected():
    _, emu, rng = _random_mesh(4, 3)
    x = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="mesh backend"):
        emu.apply(x, backend="bogus")


# ----------------- block-batched kernel (mesh_scan_blocks) ------------------

def _random_stack(m, blocks, seed=0):
    """B random same-width compiled programs on one stacked block axis."""
    return mesh._stack_meshes(
        [_random_mesh(m, 97 * seed + b)[1] for b in range(blocks)])


@pytest.mark.parametrize("x_blocked", [False, True])
@pytest.mark.parametrize("m,blocks,batch", [(12, 3, 9), (16, 4, 20)])
def test_blocked_kernel_bitexact_vs_vmapped_xla(m, blocks, batch, x_blocked):
    """The tentpole parity gate: ONE grid-folded pallas launch over the
    stacked block axis == the vmapped per-block xla scan, bit for bit
    (noise off) — shared and per-block batches, the fused per-block
    diagonal epilogue, and ragged batch tiles (blk_b=8 forces several
    partially-filled tiles, exercising the one-hot scratch cache)."""
    stacked = _random_stack(m, blocks, seed=m + blocks)
    rng = np.random.default_rng(0)
    shape = (batch, blocks, m) if x_blocked else (batch, m)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    ps = jnp.asarray(rng.normal(size=(blocks, m)).astype(np.float32))
    got = mesh._apply_stacked(stacked, x, x_blocked, backend="pallas",
                              post_scale=ps, blk_b=8)
    want = mesh._apply_stacked(stacked, x, x_blocked, backend="xla",
                               post_scale=ps)
    assert got.shape == want.shape == (batch, blocks, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_blocked_kernel_noise_off_is_statically_clean():
    """A disabled PhaseNoise (both stds 0) with a key must be the
    bit-identical program to no noise at all — std=0 may not trace any
    drift code (no seed operand) into the kernel."""
    from repro.photonics.pipeline import PhaseNoise
    stacked = _random_stack(10, 3, seed=5)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(7, 10)).astype(np.float32))
    clean = mesh._apply_stacked(stacked, x, False, backend="pallas")
    noisy = mesh._apply_stacked(stacked, x, False, backend="pallas",
                                noise=PhaseNoise(0.0, 0.0),
                                key=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(noisy), np.asarray(clean))
    # and the traced jaxpr carries no randomness: identical to clean
    key = jax.random.PRNGKey(3)
    j_clean = str(jax.make_jaxpr(lambda v: mesh._apply_stacked(
        stacked, v, False, backend="pallas"))(x))
    j_noisy = str(jax.make_jaxpr(lambda v: mesh._apply_stacked(
        stacked, v, False, backend="pallas", noise=PhaseNoise(0.0, 0.0),
        key=key))(x))
    assert j_clean == j_noisy


def test_inkernel_noise_deterministic_per_key():
    """In-kernel theta drift is a pure function of the step key: same
    key -> identical output, different key -> different draw."""
    from repro.photonics.pipeline import PhaseNoise
    stacked = _random_stack(12, 2, seed=6)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(9, 12)).astype(np.float32))
    noise = PhaseNoise(0.05, 0.0)
    fn = jax.jit(lambda k: mesh._apply_stacked(
        stacked, x, False, backend="pallas", noise=noise, key=k))
    a = np.asarray(fn(jax.random.PRNGKey(11)))
    b = np.asarray(fn(jax.random.PRNGKey(11)))
    c = np.asarray(fn(jax.random.PRNGKey(12)))
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0.0


def test_inkernel_theta_drift_matches_xla_perturb_stats():
    """The splitmix32+Box-Muller drift drawn inside the kernel must be
    the SAME noise model as the XLA ``PhaseNoise.perturb`` reference:
    zero-mean output deviation with matching spread across step keys."""
    from repro.photonics.pipeline import PhaseNoise
    stacked = _random_stack(16, 2, seed=9)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    noise = PhaseNoise(0.05, 0.0)
    clean = np.asarray(mesh._apply_stacked(stacked, x, False,
                                           backend="xla"))

    def deviations(backend):
        fn = jax.jit(lambda k: mesh._apply_stacked(
            stacked, x, False, backend=backend, noise=noise, key=k))
        return np.stack([np.asarray(fn(jax.random.PRNGKey(i))) - clean
                         for i in range(60)])

    dp, dx = deviations("pallas"), deviations("xla")
    assert abs(float(dp.mean())) < 0.01 and abs(float(dx.mean())) < 0.01
    assert float(dp.std()) > 0.0
    np.testing.assert_allclose(float(dp.std()), float(dx.std()), rtol=0.15)


# ------------------- full ONN pipeline, x64 acceptance bar ------------------

PALLAS_ORACLE_X64 = textwrap.dedent("""
    import json
    import jax, numpy as np, jax.numpy as jnp
    from repro.photonics import mesh, onn
    from repro.photonics.onn import ONNConfig

    CFGS = [
        ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                  bits=4, n_servers=2, k_inputs=2),
        ONNConfig(structure=(4, 32, 64, 32, 4), approx_layers=(),
                  bits=8, n_servers=4, k_inputs=4),
        ONNConfig(structure=(1, 4, 1), approx_layers=(), bits=2,
                  n_servers=3, k_inputs=1),
    ]
    diffs = []
    for i, cfg in enumerate(CFGS):
        params = onn.project_approx(
            onn.init_params(cfg, jax.random.PRNGKey(i)), cfg)
        hw = onn.map_to_hardware(params, cfg)
        progs = mesh.compile_hardware(hw)          # float64 under x64
        a = np.random.default_rng(i).uniform(
            0, cfg.in_scale, size=(32, cfg.structure[0]))
        want = onn.apply_hardware(hw, a, cfg)
        got = np.asarray(jax.jit(lambda x: mesh.apply_hardware(
            progs, x, cfg, backend="pallas"))(jnp.asarray(a)))
        diffs.append(float(np.abs(got - want).max()))
    print(json.dumps(diffs))
""")


def test_pallas_oracle_parity_1e6_x64():
    """Acceptance bar: the fused kernel (interpret mode on CPU) matches
    the numpy apply_hardware oracle to <= 1e-6 on every ONNConfig
    structure the suite uses, under x64."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", PALLAS_ORACLE_X64],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(JAX_ENABLE_X64="1"))
    assert r.returncode == 0, r.stderr[-2000:]
    diffs = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(d <= 1e-6 for d in diffs), diffs


# ----------------------- module / fidelity plumbing -------------------------

def test_exact_identity_symbols_pallas_backend():
    """ONNModule.symbols(fidelity='mesh', mesh_backend='pallas') keeps the
    exact-identity transfer function exact (all 27 3-server codes)."""
    module = ONNModule.exact_identity(bits=2, n_servers=3)
    codes = np.stack(np.meshgrid(*([np.arange(3)] * 3),
                                 indexing="ij")).reshape(3, -1)
    sym = encoding.pam4_encode(jnp.asarray(codes), 2)
    a = encoding.preprocess(sym, 2, module.cfg.k_inputs)
    want = np.asarray(encoding.expected_avg_symbols(sym, 2))
    got = np.asarray(module.symbols(a, fidelity="mesh",
                                    mesh_backend="pallas"))
    np.testing.assert_array_equal(got, want)


def test_mesh_scan_interpret_auto_agrees():
    """Auto-detected interpret and forced interpret=True must agree (on
    TPU this pits the compiled kernel against the interpreter)."""
    _, emu, rng = _random_mesh(16, 4)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    auto = mesh_scan(emu.signs, emu.perm, emu.ca, emu.sa, x)
    forced = mesh_scan(emu.signs, emu.perm, emu.ca, emu.sa, x,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))


# --------------------------- RunSpec threading ------------------------------

def test_runspec_mesh_backend_flag_and_roundtrip():
    from repro.api import RunSpec, SpecError
    spec = RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                              "--fidelity", "mesh",
                              "--mesh-backend", "pallas"])
    assert spec.sync.photonics.mesh_backend == "pallas"
    assert RunSpec.from_json(spec.to_json()) == spec
    # the knob only applies to the mesh fidelity
    with pytest.raises(SpecError, match="mesh-backend"):
        RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                           "--mesh-backend", "pallas"])
    # a bad value in a --spec file is a SpecError, not a raw ValueError
    with pytest.raises(SpecError, match="invalid PhotonicsConfig"):
        RunSpec.from_json_dict(
            {"sync": {"photonics": {"mesh_backend": "bogus"}}})


def test_runspec_blk_b_flag_and_roundtrip():
    from repro.api import RunSpec, SpecError
    spec = RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                              "--fidelity", "mesh",
                              "--mesh-backend", "pallas",
                              "--blk-b", "64"])
    assert spec.sync.photonics.blk_b == 64
    assert RunSpec.from_json(spec.to_json()) == spec
    # the tiling knob only applies to the mesh fidelity
    with pytest.raises(SpecError, match="blk-b"):
        RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                           "--blk-b", "64"])
    # blk_b must respect the 8-row sublane tile (config validation
    # surfaces as a SpecError through a --spec file)
    with pytest.raises(SpecError, match="invalid PhotonicsConfig"):
        RunSpec.from_json_dict(
            {"sync": {"photonics": {"fidelity": "mesh", "blk_b": 12}}})
