"""Elastic end-to-end: chaos kill/recover and reshard-resume round trips.

Multi-process counterparts to tests/test_elastic.py.  The chaos test is
the PR's headline proof: four workers over a (pods=2, dp=2) cascade,
one SIGKILLed mid-run; the survivors must re-derive the (1, 2) topology
and keep the loss descending through the reshard-resume.  The CLI tests
exercise the same reshard path through repro.launch.train directly:
(2, 2) -> (1, 2) re-zeroes the error-feedback residuals (bucketization
changed), (1, 2) -> (2, 1)-shaped mesh on the same device count restores
them, and a mesh change WITHOUT --allow-reshard is refused with a
SpecMismatchError that names the flag.
"""
import json
import subprocess
import sys

import pytest


def run_train(args, timeout=900, devices=4, expect_fail=False):
    from conftest import subprocess_env
    env = subprocess_env(
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=timeout, env=env)
    if expect_fail:
        assert r.returncode != 0, r.stdout[-2000:]
        return r
    assert r.returncode == 0, r.stderr[-3000:]
    recs = [json.loads(l) for l in r.stdout.splitlines()
            if l.startswith("{")]
    return r, recs


@pytest.mark.slow
def test_chaos_kill_one_pod_recovers(tmp_path):
    """SIGKILL one of four workers after the step-0 checkpoint: survivors
    re-form as one pod of dp=2 and finish the run with descending loss."""
    from repro.elastic.chaos import run_chaos

    result = run_chaos(tmp_path / "chaos", n_workers=4, kill_index=3,
                       kill_after_step=0, steps=12, timeout_s=840.0,
                       log=lambda *a: None)
    assert result.get("error") is None, result
    events = result["events"]
    assert len(events) == 1, events
    ev = events[0]
    assert ev["old_topology"] == [2, 2]
    assert ev["new_topology"] == [1, 2]
    assert ev["n"] == 2 and ev["n1"] == 2
    assert sorted(ev["live"]) == ["w0", "w1", "w2"]
    history = result["history"]
    assert history[-1]["step"] == 11
    losses = [r["loss"] for r in history]
    assert all(l == l and abs(l) != float("inf") for l in losses)
    post = [r["loss"] for r in history if r["step"] >= ev["step"]]
    assert len(post) >= 2 and post[-1] < post[0], post
    # the reshard changed WHERE the state lives, never what it means
    from repro.api import RunSpec
    assert result["state_fingerprint"] == \
        RunSpec(arch="minitron_4b", smoke=True).state_fingerprint()
    # the victim died by SIGKILL; every survivor exited cleanly
    codes = result["exit_codes"]
    assert codes[3] == -9 and all(c == 0 for i, c in enumerate(codes)
                                  if i != 3), codes
    # shrinking the world shrinks the modeled wire cost
    import dataclasses
    from repro.api import MeshSpec, RunSpec, SyncConfig, build
    base = RunSpec(arch="minitron_4b", smoke=True,
                   mesh=MeshSpec(pods=2, dp=2),
                   sync=SyncConfig(mode="cascade"))
    shrunk = dataclasses.replace(
        base, mesh=dataclasses.replace(base.mesh, pods=1))
    assert (build.modeled_bytes_on_wire(shrunk)
            < build.modeled_bytes_on_wire(base))


@pytest.mark.slow
def test_reshard_resume_round_trip(tmp_path):
    """(2,2) cascade -> (1,2) reshard (residuals re-zeroed) -> back to a
    4-device mesh (residual shapes match again; no re-zero message).
    Loss descends across all three leg boundaries."""
    ckpt = str(tmp_path / "ckpt")
    base = ["--arch", "minitron_4b", "--smoke-config", "--sync", "cascade",
            "--error-feedback", "--global-batch", "4", "--seq-len", "32",
            "--lr", "1e-3", "--bucket-mb", "1", "--ckpt-dir", ckpt,
            "--ckpt-every", "1"]
    _, first = run_train(base + ["--mesh", "2x1", "--pods", "2",
                                 "--steps", "3"])
    r2, second = run_train(base + ["--mesh", "2x1", "--pods", "1",
                                   "--steps", "6", "--resume",
                                   "--allow-reshard"])
    # bucketization changed (4 devices -> 2): residuals re-zeroed, loudly
    assert "residuals re-zeroed" in r2.stdout, r2.stdout[-2000:]
    assert "resharded" in r2.stdout
    assert min(r["step"] for r in second) == 3   # resumed, not restarted
    r3, third = run_train(base + ["--mesh", "1x1", "--pods", "2",
                                  "--steps", "9", "--resume",
                                  "--allow-reshard"], devices=2)
    # same flat device count (2) as the previous leg: residual bucket
    # shapes match, so sync_state is RESTORED, not re-zeroed
    assert "residuals re-zeroed" not in r3.stdout, r3.stdout[-2000:]
    assert "resharded" in r3.stdout
    assert min(r["step"] for r in third) == 6
    losses = ([r["loss"] for r in first] + [r["loss"] for r in second]
              + [r["loss"] for r in third])
    assert all(l == l and abs(l) != float("inf") for l in losses)
    assert losses[-1] < losses[0], losses


@pytest.mark.slow
def test_reshard_refused_without_allow_reshard(tmp_path):
    """A mesh change at resume time is a hard SpecMismatchError unless the
    user consents with --allow-reshard; the error says which flag."""
    ckpt = str(tmp_path / "ckpt")
    base = ["--arch", "minitron_4b", "--smoke-config", "--sync", "cascade",
            "--global-batch", "4", "--seq-len", "32", "--ckpt-dir", ckpt,
            "--ckpt-every", "1"]
    run_train(base + ["--mesh", "2x1", "--pods", "2", "--steps", "2"])
    r = run_train(base + ["--mesh", "1x1", "--pods", "2", "--steps", "4",
                          "--resume"], devices=2, expect_fail=True)
    err = r.stderr       # train.py renders SpecMismatchError as "error: ..."
    assert "different mesh shape" in err, err[-3000:]
    assert "--allow-reshard" in err
    assert "'dp': 2" in err and "'dp': 1" in err   # both shapes named


@pytest.mark.slow
def test_elastic_session_in_process_leave(tmp_path):
    """In-process elastic run on 4 forced host devices: a member leaves at
    step 2 via a callback, the session re-derives (2,2)->(1,2), fires
    on_membership_change on user callbacks, and finishes the step budget."""
    from conftest import subprocess_env
    prog = f"""
import dataclasses, json
from repro.api import (Callback, CheckpointConfig, DataConfig, ElasticConfig,
                       MeshSpec, RunSpec, SyncConfig, ElasticTrainSession)
from repro.elastic import Membership

mdir = {str(tmp_path / "members")!r}
members = [Membership(mdir, member=f"w{{i}}", heartbeat_s=0.05)
           for i in range(4)]
for m in members:
    m.join(); m.start_heartbeat()

class Leaver(Callback):
    def __init__(self):
        self.changes = []
    def on_step(self, session, record):
        if record["step"] == 2:
            members[3].leave()      # unlinks the member file immediately
    def on_membership_change(self, old_mesh, new_mesh, step):
        self.changes.append([old_mesh.pods, old_mesh.dp,
                             new_mesh.pods, new_mesh.dp, step])

spec = RunSpec(arch="minitron_4b", smoke=True, steps=8,
               data=DataConfig(vocab=0, seed=0, global_batch=4, seq_len=32),
               mesh=MeshSpec(pods=2, dp=2),
               sync=SyncConfig(mode="cascade"),
               ckpt=CheckpointConfig(dir={str(tmp_path / "ckpt")!r}, every=1),
               elastic=ElasticConfig(enabled=True, dir=mdir,
                                     heartbeat_s=0.05, allow_reshard=True))
leaver = Leaver()
sess = ElasticTrainSession(spec, callbacks=[leaver], membership=members[0])
history = sess.run()
for m in members:
    m.stop_heartbeat()
print("RESULT", json.dumps({{
    "events": sess.events, "changes": leaver.changes,
    "steps": [r["step"] for r in history],
    "losses": [r["loss"] for r in history]}}))
"""
    env = subprocess_env(
        XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert len(out["events"]) == 1, out["events"]
    ev = out["events"][0]
    assert ev["old_topology"] == [2, 2] and ev["new_topology"] == [1, 2]
    assert out["changes"] == [[2, 2, 1, 2, ev["step"]]]
    assert out["steps"][-1] == 7        # finished the budget post-reshard
    assert out["losses"][-1] < out["losses"][0]
