"""MZI decomposition, matrix approximation, and area model (Tables I/II)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.photonics import approx, area, mzi


@pytest.mark.parametrize("m", [2, 4, 8, 16, 32])
def test_givens_reconstruction(m):
    rng = np.random.default_rng(m)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    prog = mzi.givens_decompose(q)
    assert len(prog.rotations) <= m * (m - 1) // 2
    np.testing.assert_allclose(mzi.reconstruct(prog), q, atol=1e-9)


def test_givens_rejects_nonorthogonal():
    with pytest.raises(ValueError):
        mzi.givens_decompose(np.ones((4, 4)))


@pytest.mark.parametrize("shape", [(16, 16), (32, 16), (16, 32), (64, 4)])
def test_svd_programming(shape):
    rng = np.random.default_rng(0)
    w = rng.normal(size=shape)
    pu, s, pv = mzi.program_matrix_svd(w)
    x = rng.normal(size=(shape[1], 5))
    np.testing.assert_allclose(mzi.apply_programmed_svd(pu, s, pv, x),
                               w @ x, atol=1e-8)


@pytest.mark.parametrize("shape", [(8, 8), (16, 8), (8, 16), (64, 4)])
def test_approx_block_structure(shape):
    """W_a = Sigma_a U_a: each block must have orthogonal scaled rows, and
    re-approximating is a fixed point."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    wa = approx.approx_matrix(w)
    wa2 = approx.approx_matrix(wa)
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wa2), atol=1e-4)
    # projection reduces (or keeps) distance: ||W - Wa|| <= ||W|| (Procrustes)
    assert float(jnp.linalg.norm(w - wa)) <= float(jnp.linalg.norm(w))


def test_approx_exact_for_structured_matrix():
    """A matrix that already is diag @ orthogonal is reproduced exactly."""
    rng = np.random.default_rng(2)
    q, _ = np.linalg.qr(rng.normal(size=(16, 16)))
    w = jnp.asarray((np.diag(rng.normal(size=16)) @ q).astype(np.float32))
    wa = approx.approx_matrix(w)
    np.testing.assert_allclose(np.asarray(wa), np.asarray(w), atol=1e-5)


TABLE1 = [
    ((4, 64, 128, 256, 128, 64, 4), set(range(1, 7)), 0.393),
    ((4, 64, 128, 256, 512, 256, 128, 64, 4), set(range(2, 8)), 0.409),
    ((4, 64, 128, 256, 512, 1024, 512, 256, 128, 64, 4), set(range(2, 10)), 0.404),
    ((4, 64, 128, 256, 512, 256, 128, 64, 8), {4, 5, 6}, 0.493),
]


@pytest.mark.parametrize("structure,approx_layers,paper", TABLE1)
def test_area_ratio_matches_table1(structure, approx_layers, paper):
    r = area.area_ratio(list(structure), approx_layers)
    assert abs(r - paper) < 0.005, (r, paper)


TABLE2 = [({4, 5, 6}, 0.493), ({4, 5, 6, 7}, 0.479), ({4, 5, 6, 7, 8}, 0.474),
           ({3, 4, 5, 6}, 0.437), ({3, 4, 5, 6, 7}, 0.422)]


@pytest.mark.parametrize("layers,paper", TABLE2)
def test_area_ratio_matches_table2(layers, paper):
    st4 = [4, 64, 128, 256, 512, 256, 128, 64, 8]
    assert abs(area.area_ratio(st4, layers) - paper) < 0.005


def test_mzi_count_halved_by_approx():
    # square matrix: approx saves the V mesh => ~50%
    full = area.mzi_count_svd(64, 64)
    ap = area.mzi_count_approx(64, 64)
    assert 0.45 < ap / full < 0.55
