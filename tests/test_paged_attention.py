"""kernels.paged_attention: interpreted-kernel parity against the
gather oracle (paged_gather -> decode_attention), null-page invariance
under garbage pool contents, kv_dtype storage tolerance, dispatch
policy, and the gqa_decode_paged off-TPU fallback equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat  # noqa: F401  (jax.shard_map shim on 0.4.x)
from repro.kernels import paged_attention as pk
from repro.models.layers import ShardCtx, decode_attention, paged_gather


def _case(b=4, h=4, hkv=2, ps=4, nb=3, hd=8, n_pages=None, seed=0,
          dtype=jnp.float32):
    """Random pool + per-slot page tables + a mix of lengths (0, mid-page,
    page-aligned, full allocation).  Pages beyond a slot's length point
    at the null page 0, which holds zeros, like the engine maintains."""
    rng = np.random.default_rng(seed)
    n_pages = n_pages or 1 + b * nb
    q = jnp.asarray(rng.normal(size=(b, h, 1, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(n_pages, hkv, ps, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(n_pages, hkv, ps, hd)), dtype)
    cap = nb * ps
    base = [0, ps - 1, ps, cap]                         # the edge cases
    lengths = np.asarray((base * b)[:b], np.int32)
    table = np.zeros((b, nb), np.int32)
    for i in range(b):
        used = -(-int(lengths[i]) // ps)
        table[i, :used] = 1 + i * nb + np.arange(used)
    # null page is all-zero (the pool invariant write_prompts maintains)
    kp = kp.at[0].set(0)
    vp = vp.at[0].set(0)
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lengths)


def _oracle(q, kp, vp, table, lengths):
    return decode_attention(ShardCtx(), q, paged_gather(kp, table),
                            paged_gather(vp, table), lengths)


# ------------------------------------------------------------- parity
@pytest.mark.parametrize("b,h,hkv,ps,nb,hd", [
    (4, 4, 2, 4, 3, 8),      # GQA rep=2, the serving smoke shape family
    (2, 4, 4, 8, 2, 16),     # MHA rep=1
    (8, 8, 2, 4, 4, 8),      # rep=4, full occupancy bucket
    (1, 2, 1, 16, 1, 32),    # single slot, single page
])
def test_kernel_matches_gather_oracle(b, h, hkv, ps, nb, hd):
    """Interpreted kernel vs the gather path across shapes and lengths
    (0, mid-page, page-aligned, full): equal to float associativity of
    the online softmax."""
    q, kp, vp, table, lengths = _case(b, h, hkv, ps, nb, hd)
    got = pk.paged_attention(q, kp, vp, table, lengths, interpret=True)
    ref = _oracle(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_kernel_ignores_null_page_garbage():
    """Poisoning the null page changes NOTHING for any slot with >= 1
    valid position (all the engine ever attends — pad rows get valid
    count 1 at position 0): masking is by position-vs-length, never by
    trusting pool contents.  Holds for the kernel and the gather oracle
    alike.  (A length-0 row is all-masked -> uniform weights -> mean of
    its pages; both paths produce the same garbage and nothing reads it.)"""
    q, kp, vp, table, lengths = _case(seed=3)
    live = np.asarray(lengths) > 0
    clean_k = pk.paged_attention(q, kp, vp, table, lengths, interpret=True)
    clean_o = _oracle(q, kp, vp, table, lengths)
    kp = kp.at[0].set(1e4)
    vp = vp.at[0].set(-1e4)
    dirty_k = pk.paged_attention(q, kp, vp, table, lengths, interpret=True)
    dirty_o = _oracle(q, kp, vp, table, lengths)
    np.testing.assert_array_equal(np.asarray(dirty_k)[live],
                                  np.asarray(clean_k)[live])
    np.testing.assert_array_equal(np.asarray(dirty_o)[live],
                                  np.asarray(clean_o)[live])


def test_kernel_masks_partial_page_tail():
    """Stale garbage in the tail of a slot's LAST page (positions >=
    length, same page) contributes exactly nothing."""
    q, kp, vp, table, lengths = _case(seed=4)
    clean = pk.paged_attention(q, kp, vp, table, lengths, interpret=True)
    # slot 1 has length ps-1: poison the final position of its only page
    pg = int(table[1, 0])
    kp = kp.at[pg, :, -1].set(1e4)
    vp = vp.at[pg, :, -1].set(-1e4)
    dirty = pk.paged_attention(q, kp, vp, table, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(dirty[1]), np.asarray(clean[1]))


def test_kernel_bf16_pool_within_storage_tolerance():
    """bf16 page storage vs f32 (ServeConfig.kv_dtype): same f32
    accumulate, the only loss is the bf16 rounding of the stored K/V —
    tolerance-gated at bf16 precision, and the f32 kernel result stays
    tight against the f32 oracle."""
    q, kp, vp, table, lengths = _case(seed=5, hd=16)
    ref = pk.paged_attention(q, kp, vp, table, lengths, interpret=True)
    got = pk.paged_attention(q, kp.astype(jnp.bfloat16),
                             vp.astype(jnp.bfloat16), table, lengths,
                             interpret=True)
    assert got.dtype == q.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=5e-2, rtol=5e-2)
    # and the bf16 oracle agrees with the bf16 kernel much tighter than
    # that storage error (both consume the same rounded pages)
    ref16 = _oracle(q, kp.astype(jnp.bfloat16).astype(jnp.float32),
                    vp.astype(jnp.bfloat16).astype(jnp.float32),
                    table, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref16),
                               atol=2e-6, rtol=2e-6)


# ----------------------------------------------------------- dispatch
def test_use_kernel_dispatch_policy():
    """Explicit flag > FORCE_KERNEL hook > platform (CPU CI: False)."""
    assert pk.use_kernel(True) and not pk.use_kernel(False)
    assert pk.use_kernel() == (jax.default_backend() == "tpu")
    old = pk.FORCE_KERNEL
    try:
        pk.FORCE_KERNEL = True
        assert pk.use_kernel() and not pk.use_kernel(False)
        pk.FORCE_KERNEL = False
        assert not pk.use_kernel() and pk.use_kernel(True)
    finally:
        pk.FORCE_KERNEL = old


def test_gqa_decode_paged_backend_fallback_is_bit_exact():
    """Off-TPU, backend='paged' dispatches to the gather math: bitwise
    equal to backend='gather' (the property the CPU engine parity tests
    lean on); FORCE_KERNEL swaps in the interpreted kernel, which agrees
    to tolerance only."""
    from repro import configs
    from repro.models.blocks import gqa_decode_paged

    cfg = configs.get_smoke("minitron_4b")
    ctx = ShardCtx()
    rng = np.random.default_rng(6)
    d, hd = cfg.d_model, cfg.hd
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    b, ps, nb = 2, 4, 2
    n_pages = 1 + b * nb
    p = {"norm": jnp.ones((d,), jnp.float32),
         "wq": jnp.asarray(rng.normal(size=(d, h * hd)) * 0.1, jnp.float32),
         "wk": jnp.asarray(rng.normal(size=(d, hkv * hd)) * 0.1, jnp.float32),
         "wv": jnp.asarray(rng.normal(size=(d, hkv * hd)) * 0.1, jnp.float32),
         "wo": jnp.asarray(rng.normal(size=(h * hd, d)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(b, 1, d)), jnp.float32)
    pool = {"k": jnp.asarray(rng.normal(size=(n_pages, hkv, ps, hd)),
                             jnp.float32).at[0].set(0),
            "v": jnp.asarray(rng.normal(size=(n_pages, hkv, ps, hd)),
                             jnp.float32).at[0].set(0)}
    table = jnp.asarray(np.arange(1, 1 + b * nb).reshape(b, nb), jnp.int32)
    lengths = jnp.asarray([3, 5], jnp.int32)

    # sp_out psums over the 'model' axis -> bind a 1-device mesh
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))
    kv_specs = {"k": P(), "v": P()}

    def run(backend):
        def f(p_, x_, kv_):
            return gqa_decode_paged(ctx, cfg, p_, x_, lengths, kv_, table,
                                    backend=backend)
        return jax.shard_map(
            f, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), p), P(), kv_specs),
            out_specs=(P(), kv_specs), check_vma=False)(p, x, pool)

    out_g, kv_g = run("gather")
    out_p, kv_p = run("paged")
    np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out_p))
    np.testing.assert_array_equal(np.asarray(kv_g["k"]),
                                  np.asarray(kv_p["k"]))
    old = pk.FORCE_KERNEL
    try:
        pk.FORCE_KERNEL = True
        out_k, _ = run("paged")
    finally:
        pk.FORCE_KERNEL = old
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_g),
                               atol=1e-5, rtol=1e-5)
    assert not np.array_equal(np.asarray(out_k), np.asarray(out_g))
