"""Test-suite wiring: platform pinning and subprocess environments.

The suite is a CPU suite (host-device meshes via XLA_FLAGS); pin
JAX_PLATFORMS before any jax import so jax does not spend a minute
probing for accelerator runtimes that are not attached.  An explicit
JAX_PLATFORMS in the environment still wins.

``hypothesis`` is a REAL optional dependency: property-based tests
(test_encoding.py, test_photonics_properties.py) call
``pytest.importorskip("hypothesis")`` and skip cleanly when the package
is absent (this container); CI installs it and runs them for real.  The
old deterministic miniature stand-in that used to live here silently
downgraded the property tests to 25 fixed samples — gone.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def subprocess_env(**extra):
    """Environment for test subprocesses (multi-device host runs): minimal
    PATH plus the same platform pin as the parent, so children skip the
    accelerator-runtime probe too.  Import from tests as
    ``from conftest import subprocess_env``."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    env.update(extra)
    return env
