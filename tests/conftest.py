"""Test-suite wiring: platform pinning and optional-dependency gates.

The container image may lack ``hypothesis`` (and nothing may be pip
installed); when it is missing we register a deterministic miniature
stand-in providing the tiny surface the suite uses (@given/@settings and
the integers/floats/lists strategies), sampling a fixed number of
seeded examples so the property tests still exercise the code.

The suite is a CPU suite (host-device meshes via XLA_FLAGS); pin
JAX_PLATFORMS before any jax import so jax does not spend a minute
probing for accelerator runtimes that are not attached.  An explicit
JAX_PLATFORMS in the environment still wins.
"""
import os
import random
import sys
import types

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def subprocess_env(**extra):
    """Environment for test subprocesses (multi-device host runs): minimal
    PATH plus the same platform pin as the parent, so children skip the
    accelerator-runtime probe too.  Import from tests as
    ``from conftest import subprocess_env``."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    env.update(extra)
    return env

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value, max_value, allow_nan=True, allow_infinity=True):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda r: [elements.sample(r)
                       for _ in range(r.randint(min_size, max_size))])

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — the wrapper must present a
            # zero-arg signature or pytest treats the strategy-filled
            # parameters as fixtures.
            def wrapper():
                rng = random.Random(0)
                for _ in range(25):
                    extra = [s.sample(rng) for s in arg_strategies]
                    named = {n: s.sample(rng)
                             for n, s in kw_strategies.items()}
                    fn(*extra, **named)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(*a, **kw):
        return lambda fn: fn

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.lists = lists
    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    stub.strategies = strategies
    stub.__is_repro_stub__ = True
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
