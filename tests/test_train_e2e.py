"""End-to-end training: loss decreases; checkpoint resume is exact;
OptINC sync trains as well as exact psum on the paper's LLaMA config."""
import json
import subprocess
import sys

import pytest


def run_train(args, timeout=900):
    from conftest import subprocess_env
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=timeout,
        env=subprocess_env())
    assert r.returncode == 0, r.stderr[-3000:]
    recs = [json.loads(l) for l in r.stdout.splitlines()
            if l.startswith("{")]
    return recs


@pytest.mark.slow
def test_loss_decreases_optinc():
    recs = run_train(["--arch", "paper_llama", "--smoke-config",
                      "--sync", "optinc", "--steps", "30",
                      "--global-batch", "8", "--seq-len", "128",
                      "--lr", "1e-3"])
    first = sum(r["loss"] for r in recs[:5]) / 5
    last = sum(r["loss"] for r in recs[-5:]) / 5
    assert last < first - 0.5, (first, last)


@pytest.mark.slow
def test_resume_is_exact(tmp_path):
    base = ["--arch", "minitron_4b", "--smoke-config", "--sync", "optinc",
            "--global-batch", "4", "--seq-len", "64", "--lr", "1e-3",
            "--ckpt-every", "5"]
    # reference: uninterrupted 10-step run
    full = run_train(base + ["--steps", "10",
                             "--ckpt-dir", str(tmp_path / "ref")])
    # "preempted" run: stops at step 5 (checkpoint exists at step 4)...
    run_train(base + ["--steps", "5", "--ckpt-dir", str(tmp_path / "re")])
    # ...then a fresh process resumes and finishes
    resumed = run_train(base + ["--steps", "10", "--resume",
                                "--ckpt-dir", str(tmp_path / "re")])
    f = {r["step"]: r["loss"] for r in full}
    g = {r["step"]: r["loss"] for r in resumed}
    assert min(g) == 5  # really resumed, not restarted
    for s in (6, 7, 8, 9):
        assert abs(f[s] - g[s]) < 1e-3, (s, f[s], g[s])


@pytest.mark.slow
def test_error_feedback_resume_exact(tmp_path):
    """--resume with --error-feedback restores the residual sync_state from
    the checkpoint: the resumed steps equal an uninterrupted run EXACTLY
    (before sync_state was checkpointed, residuals restarted from zero and
    the trajectories diverged)."""
    base = ["--arch", "minitron_4b", "--smoke-config", "--sync", "optinc",
            "--error-feedback", "--global-batch", "2", "--seq-len", "32",
            "--lr", "1e-3", "--ckpt-every", "2"]
    full = run_train(base + ["--steps", "6",
                             "--ckpt-dir", str(tmp_path / "ref")])
    run_train(base + ["--steps", "4", "--ckpt-dir", str(tmp_path / "re")])
    resumed = run_train(base + ["--steps", "6", "--resume",
                                "--ckpt-dir", str(tmp_path / "re")])
    f = {r["step"]: r["loss"] for r in full}
    g = {r["step"]: r["loss"] for r in resumed}
    assert min(g) == 4  # really resumed, not restarted
    for s in (4, 5):
        assert f[s] == g[s], (s, f[s], g[s])
