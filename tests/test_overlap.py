"""Streaming collective engine (SyncConfig.overlap): bit-exactness vs the
barrier path, frozen-jaxpr gate on the overlap-off scan, readiness-ordered
dispatch, the time-on-wire model's overlap invariant, and the --overlap
CLI surface."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.api import build
from repro.api.spec import MeshSpec, RunSpec
from repro.collectives import (SyncConfig, get_backend, register_backend,
                               sync_gradients)
from repro.collectives.bucketizer import (flatten_concat, launch_order,
                                          make_layout, unbucketize)
from repro.launch import steps
from repro.launch.mesh import make_mesh


def _tree():
    rng = np.random.default_rng(3)
    # three leaves, 1024-byte buckets (256 f32 elems): leaf boundaries and
    # bucket boundaries interleave, with a ragged tail
    return {"a": jnp.asarray(rng.normal(size=(600,)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(15, 20)).astype(np.float32)),
            "c": jnp.asarray(rng.normal(size=(77,)).astype(np.float32))}


def _run(f, tree, *extra):
    mesh = make_mesh((1,), ("data",))
    spec = {k: P() for k in tree}
    fn = jax.shard_map(f, mesh=mesh,
                       in_specs=(spec,) + (P(),) * len(extra),
                       out_specs=(spec, P()), check_vma=False)
    return jax.jit(fn)(tree, *extra)


# ------------------- overlap on == overlap off, bit for bit ----------------

@pytest.mark.parametrize("error_feedback", [False, True])
def test_overlap_bitexact_vs_barrier(error_feedback):
    tree = _tree()
    size = sum(int(v.size) for v in tree.values())
    residual = jnp.asarray(
        np.random.default_rng(9).normal(size=(size,)).astype(np.float32))
    key = jax.random.PRNGKey(7)
    outs = {}
    for overlap in (False, True):
        cfg = SyncConfig(mode="optinc", axes=("data",), bits=4, block=64,
                         bucket_bytes=1024, error_feedback=error_feedback,
                         overlap=overlap)

        def f(t, r):
            return sync_gradients(t, cfg, key,
                                  r if error_feedback else None)

        synced, res = _run(f, tree, residual)
        outs[overlap] = (synced, res)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(outs[False][0][k]),
                                      np.asarray(outs[True][0][k]), err_msg=k)
    if error_feedback:
        np.testing.assert_array_equal(np.asarray(outs[False][1]),
                                      np.asarray(outs[True][1]))
    else:
        assert outs[False][1] is None and outs[True][1] is None


# -------------------- overlap off: frozen barrier jaxpr --------------------

def test_overlap_off_jaxpr_matches_pre_streaming_reference():
    """The barrier path must stay byte-for-byte what it was before the
    streaming engine landed: flatten-concat + residual add + ONE lax.scan
    over the stacked full buckets + the unrolled ragged tail.  The
    reference below IS that path (inlined); jaxpr-string equality means
    the overlap=False dispatch did not change shape, order, or math."""
    cfg = SyncConfig(mode="optinc", axes=("data",), bits=4, block=64,
                     bucket_bytes=1024)
    backend = get_backend("optinc")

    def current(t, key):
        out, _ = sync_gradients(t, cfg, key, None)
        return out

    def reference(t, key):
        leaves, treedef = jax.tree.flatten(t)
        layout = make_layout(leaves, cfg.bucket_bytes)
        flat = flatten_concat(leaves)
        buckets = [flat[s:e] for s, e in layout.bounds]
        keys = jax.random.split(key, len(buckets))
        n_full = sum(1 for s, e in layout.bounds
                     if e - s == layout.bucket_elems)
        outs, errs = [], []
        if n_full >= 2:
            xs = jnp.stack(buckets[:n_full])
            _, (out_s, err_s) = jax.lax.scan(
                lambda c, bk: (c, backend.sync(bk[0], cfg, bk[1])),
                None, (xs, keys[:n_full]))
            outs = list(out_s)
            # the historical path listed the scan's error output too (the
            # iteration traces index ops even when feedback is off) —
            # replicate it so the jaxprs compare equal
            errs = list(err_s) if err_s is not None else [None] * n_full
            buckets, keys = buckets[n_full:], keys[n_full:]
        for b, k in zip(buckets, keys):
            out, err = backend.sync(b, cfg, k)
            outs.append(out)
            errs.append(err)
        return jax.tree.unflatten(treedef, unbucketize(outs, layout))

    tree = _tree()
    mesh = make_mesh((1,), ("data",))
    spec = {k: P() for k in tree}

    def jaxpr_of(f):
        fn = jax.shard_map(f, mesh=mesh, in_specs=(spec, P()),
                           out_specs=spec, check_vma=False)
        return str(jax.make_jaxpr(fn)(tree, jax.random.PRNGKey(7)))

    assert jaxpr_of(current) == jaxpr_of(reference)


# ----------------------- readiness-ordered dispatch ------------------------

def test_streaming_dispatch_follows_launch_order():
    """A recording backend observes the TRACE order of bucket syncs: with
    the default reverse-emission readiness the ragged tail (end of concat
    space = first gradients out of backward) must go first."""
    trace_log = []

    class Recorder:
        def sync(self, flat, cfg, key):
            trace_log.append(int(flat.shape[0]))
            return flat, None

        def bytes_on_wire(self, nbytes, n, bits):
            return 0.0

        def time_on_wire(self, nbytes, n, bits, overlap=False,
                         bucket_bytes=0):
            return 0.0

    register_backend("record-test", Recorder(), overwrite=True)
    tree = _tree()  # 977 elems / 256-elem buckets -> 3 full + 209 tail
    cfg = SyncConfig(mode="record-test", axes=("data",), bucket_bytes=1024,
                     overlap=True)

    def f(t, key):
        return sync_gradients(t, cfg, key, None)

    _run(f, tree, jax.random.PRNGKey(0))
    layout = make_layout(jax.tree.leaves(tree), 1024)
    want = [layout.bounds[b][1] - layout.bounds[b][0]
            for b in launch_order(layout)]
    assert trace_log[: layout.n_buckets] == want
    assert trace_log[0] == 209  # the tail launches first


def test_grad_readiness_reverse_emission():
    assert steps.grad_readiness(range(4), 4) == (3, 2, 1, 0)
    # a leaf GROUP keeps its global backward ranks, not group-local ones
    assert steps.grad_readiness([0, 2], 5) == (4, 2)


# ------------------------- time-on-wire invariant --------------------------

@pytest.mark.parametrize("mode", ["psum", "ring", "optinc", "cascade"])
def test_time_on_wire_overlap_never_worse(mode):
    b = get_backend(mode)
    for nbytes in (2e3, 2e6, 86e6, 1e9):
        for n in (2, 4, 16, 64):
            for bb in (2 ** 16, 4 * 2 ** 20, 64 * 2 ** 20):
                off = b.time_on_wire(nbytes, n, 8, overlap=False,
                                     bucket_bytes=bb)
                on = b.time_on_wire(nbytes, n, 8, overlap=True,
                                    bucket_bytes=bb)
                assert 0 < on <= off, (mode, nbytes, n, bb, on, off)


def test_time_on_wire_shapes():
    # electrical backends: overlap is a no-op (no circuit to reconfigure)
    for mode in ("psum", "ring"):
        b = get_backend(mode)
        assert b.time_on_wire(1e6, 4, 8, overlap=True) == \
            b.time_on_wire(1e6, 4, 8, overlap=False)
    # optical backends strictly gain once there are >= 2 buckets
    for mode in ("optinc", "cascade"):
        b = get_backend(mode)
        assert b.time_on_wire(86e6, 4, 8, overlap=True) < \
            b.time_on_wire(86e6, 4, 8, overlap=False)


def test_modeled_time_on_wire_runspec():
    spec = RunSpec(arch="paper_llama", smoke=True,
                   mesh=MeshSpec(pods=2, dp=2),
                   sync=SyncConfig(mode="cascade"))
    off = build.modeled_time_on_wire(spec, overlap=False)
    on = build.modeled_time_on_wire(spec, overlap=True)
    assert 0 < on < off
    # the spec's own overlap flag is the default
    import dataclasses
    spec_on = dataclasses.replace(
        spec, sync=dataclasses.replace(spec.sync, overlap=True))
    assert build.modeled_time_on_wire(spec_on) == on


# ------------------------------ CLI surface --------------------------------

def test_overlap_cli_roundtrip():
    spec = RunSpec.from_args(["--sync", "cascade", "--overlap"])
    assert spec.sync.overlap is True
    assert spec.mesh.pods == 2  # cascade auto-pods unaffected
    assert RunSpec.from_json(spec.to_json()) == spec
    assert RunSpec.from_args(["--steps", "2"]).sync.overlap is False


# ----------------- multi-device cascade parity (subprocess) ----------------

OVERLAP_CASCADE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.collectives import SyncConfig, sync_gradients
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2), ("pod", "data"))
    rng = np.random.default_rng(0)
    g = rng.normal(size=(4 * 512,)).astype(np.float32)
    outs = {}
    for overlap in (False, True):
        cfg = SyncConfig(mode="cascade", axes=("pod", "data"), bits=8,
                         block=128, bucket_bytes=1024, overlap=overlap)

        def f(x):
            out, _ = sync_gradients([x], cfg, None, None)
            return out[0]

        fn = jax.shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                           out_specs=P(("pod", "data")), check_vma=False)
        outs[overlap] = np.asarray(jax.jit(fn)(jnp.asarray(g)))
    print(json.dumps(
        {"max_abs_diff": float(np.abs(outs[True] - outs[False]).max())}))
""")


@pytest.mark.slow
def test_cascade_overlap_bitexact_2x2():
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", OVERLAP_CASCADE_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["max_abs_diff"] == 0.0
