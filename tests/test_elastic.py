"""repro.elastic: membership registry, topology derivation, the
ResumeCompat verdict surface, the ElasticConfig spec/CLI round-trip, the
watchdog suspect-escalation, the ONN-cache warm path across a shrink,
and the single-axis (N2 == 1) cascade degrade.

The multi-process chaos run and the subprocess reshard-resume round-trip
live in test_elastic_chaos.py (slow)."""
import dataclasses
import time

import pytest

from repro.api import (CheckpointConfig, ElasticConfig, MeshSpec,
                       ResumeCompat, RunSpec, SpecError, SpecMismatchError,
                       StragglerWatchdog, SyncConfig, check_resume_compat,
                       default_callbacks, validate_resume_compat)
from repro.elastic import ElasticError, Membership, derive_topology, \
    member_pod


def tiny_spec(**kw):
    base = dict(arch="minitron_4b", smoke=True, steps=4)
    base.update(kw)
    return RunSpec(**base)


# ------------------------------------------------------------ membership
def test_membership_join_beat_live(tmp_path):
    a = Membership(tmp_path, member="w0", heartbeat_s=0.1)
    b = Membership(tmp_path, member="w1", heartbeat_s=0.1)
    a.join()
    b.join()
    obs = Membership(tmp_path, heartbeat_s=0.1)   # observer handle
    assert obs.live() == ("w0", "w1")
    # liveness is a time window: a stale beat drops the member
    now = time.time()
    assert obs.live(now=now + 10.0) == ()
    a.beat(now=now + 10.0)
    assert obs.live(now=now + 10.0) == ("w0",)
    a.leave()
    assert obs.live(now=now + 10.0) == ()


def test_membership_observer_cannot_join(tmp_path):
    with pytest.raises(ValueError, match="observer"):
        Membership(tmp_path).join()


def test_membership_suspect_and_clear(tmp_path):
    w = Membership(tmp_path, member="w0", heartbeat_s=0.1)
    w.join()
    obs = Membership(tmp_path, member="leader", heartbeat_s=0.1)
    obs.suspect("w0", reason="straggling")
    assert "w0" not in obs.live()
    # a LATER beat from the accused member re-admits it
    time.sleep(0.02)
    w.beat()
    assert "w0" in obs.live()


def test_membership_heartbeat_thread(tmp_path):
    w = Membership(tmp_path, member="w0", heartbeat_s=0.05)
    w.join()
    w.start_heartbeat()
    try:
        first = w.members()["w0"]["time"]
        deadline = time.time() + 2.0
        while time.time() < deadline:
            if w.members()["w0"]["time"] > first:
                break
            time.sleep(0.02)
        assert w.members()["w0"]["time"] > first
    finally:
        w.stop_heartbeat()


# ------------------------------------------------------------ topology
def test_derive_topology_matrix():
    base = MeshSpec(dp=2, tp=1, pods=2)
    assert derive_topology(4, base) is base            # full world
    assert derive_topology(5, base) is base            # spares don't grow
    assert derive_topology(3, base).pods == 1          # one pod drained
    assert derive_topology(2, base).pods == 1
    shrunk = derive_topology(2, base)
    assert (shrunk.dp, shrunk.tp) == (2, 1)            # dp/tp untouched
    with pytest.raises(ElasticError, match="full pod"):
        derive_topology(1, base)
    assert [member_pod(i, base) for i in range(4)] == [0, 0, 1, 1]


# ------------------------------------------------------------ ResumeCompat
def test_resume_compat_verdict_matrix():
    spec = tiny_spec(mesh=MeshSpec(dp=2, pods=2))
    # exact: non-structural fields may drift freely
    tweaked = dataclasses.replace(
        spec, steps=99, optim=dataclasses.replace(spec.optim, lr=5e-5))
    v = check_resume_compat(spec, tweaked)
    assert (v.verdict, v.ok, v.state_diff, v.shape_diff) == \
        ("exact", True, (), ())
    # reshardable: only the mesh moved
    shrunk = dataclasses.replace(
        spec, mesh=dataclasses.replace(spec.mesh, pods=1))
    v = check_resume_compat(spec, shrunk)
    assert (v.verdict, v.ok) == ("reshardable", True)
    assert v.shape_diff == ("mesh",) and not v.state_diff
    assert "mesh" in v.detail
    # incompatible: state-structure fields differ — named in the verdict
    other = dataclasses.replace(
        spec, optim=dataclasses.replace(spec.optim, moment_dtype="bfloat16"))
    v = check_resume_compat(spec, other)
    assert (v.verdict, v.ok) == ("incompatible", False)
    assert "moment_dtype" in v.state_diff


def test_validate_resume_compat_gating():
    spec = tiny_spec(mesh=MeshSpec(dp=2, pods=2))
    shrunk = dataclasses.replace(
        spec, mesh=dataclasses.replace(spec.mesh, pods=1))
    # mesh change without consent: raises, pointing at the gate flag
    with pytest.raises(SpecMismatchError, match="allow-reshard"):
        validate_resume_compat(spec, shrunk)
    v = validate_resume_compat(spec, shrunk, allow_reshard=True)
    assert isinstance(v, ResumeCompat) and v.verdict == "reshardable"
    # incompatible raises REGARDLESS of allow_reshard (unchanged contract)
    other = dataclasses.replace(
        spec, sync=dataclasses.replace(spec.sync, error_feedback=True))
    with pytest.raises(SpecMismatchError, match="error_feedback"):
        validate_resume_compat(spec, other, allow_reshard=True)


def test_fingerprint_split_covers_legacy():
    spec = tiny_spec()
    merged = {**spec.state_fingerprint(), **spec.shape_fingerprint()}
    assert merged == spec.compat_fingerprint()
    assert set(spec.state_fingerprint()) & set(spec.shape_fingerprint()) \
        == set()
    assert "mesh" in spec.shape_fingerprint()
    for k in ("arch", "smoke", "moment_dtype", "error_feedback"):
        assert k in spec.state_fingerprint()


# ------------------------------------------------------------ spec surface
def test_elastic_config_json_and_cli_roundtrip(tmp_path):
    spec = tiny_spec(
        elastic=ElasticConfig(enabled=True, dir="m", heartbeat_s=0.5,
                              timeout_s=2.0, allow_reshard=True,
                              evict_after=3),
        ckpt=CheckpointConfig(dir=str(tmp_path)))
    assert RunSpec.from_json(spec.to_json()) == spec
    cli = RunSpec().apply_cli(
        {"elastic": True, "heartbeat_s": 0.5, "allow_reshard": True,
         "members_dir": "m", "evict_after": 3,
         "ckpt_dir": str(tmp_path)})
    assert cli.elastic == ElasticConfig(enabled=True, dir="m",
                                        heartbeat_s=0.5, allow_reshard=True,
                                        evict_after=3)
    # default registry location hangs off the checkpoint dir
    assert ElasticConfig().members_dir("/ck") == "/ck/members"
    assert ElasticConfig(dir="/m").members_dir("/ck") == "/m"


def test_elastic_validation_rules(tmp_path):
    # psum has no topology to re-derive
    with pytest.raises(SpecError, match="psum"):
        tiny_spec(sync=SyncConfig(mode="psum"),
                  elastic=ElasticConfig(enabled=True),
                  ckpt=CheckpointConfig(dir=str(tmp_path))).validate()
    # elastic resumes from checkpoints: ckpt.dir required
    with pytest.raises(SpecError, match="ckpt-dir"):
        tiny_spec(elastic=ElasticConfig(enabled=True)).validate()
    # static cascade still needs two pods...
    with pytest.raises(SpecError, match="pod"):
        tiny_spec(sync=SyncConfig(mode="cascade")).validate()
    # ...but an elastic (or reshard-consenting) run may shrink to one
    tiny_spec(sync=SyncConfig(mode="cascade"),
              elastic=ElasticConfig(allow_reshard=True)).validate()
    tiny_spec(sync=SyncConfig(mode="cascade"),
              elastic=ElasticConfig(enabled=True),
              ckpt=CheckpointConfig(dir=str(tmp_path))).validate()
    with pytest.raises(ValueError, match="heartbeat_s"):
        ElasticConfig(heartbeat_s=0)


# ------------------------------------------------------------ watchdog
class _FakeMembership:
    def __init__(self):
        self.calls = []

    def suspect(self, member, reason=""):
        self.calls.append((member, reason))


def test_watchdog_escalates_after_consecutive_flags():
    mem = _FakeMembership()
    wd = StragglerWatchdog(factor=2.0, window=50, warmup=3, evict_after=2,
                           membership=mem, member="w1")
    for _ in range(6):
        wd.on_step_end(None, {"time_s": 0.1})
    wd.on_step_end(None, {"time_s": 5.0})        # flag 1: streak 1
    assert mem.calls == []
    rec = {"time_s": 5.0}
    wd.on_step_end(None, rec)                    # flag 2: escalate
    assert [c[0] for c in mem.calls] == ["w1"]
    assert "consecutive" in mem.calls[0][1]
    assert rec["suspected"] == "w1"
    wd.on_step_end(None, {"time_s": 5.0})        # already reported: once
    assert len(mem.calls) == 1


def test_watchdog_clean_step_resets_streak():
    mem = _FakeMembership()
    wd = StragglerWatchdog(factor=2.0, window=50, warmup=3, evict_after=2,
                           membership=mem, member="w1")
    for _ in range(6):
        wd.on_step_end(None, {"time_s": 0.1})
    wd.on_step_end(None, {"time_s": 5.0})        # streak 1
    wd.on_step_end(None, {"time_s": 0.1})        # clean: reset
    wd.on_step_end(None, {"time_s": 5.0})        # streak 1 again
    assert mem.calls == []
    # per-rank streaks: a different rank's flag is its own streak
    wd.on_step_end(None, {"time_s": 5.0, "rank": "w2"})
    assert mem.calls == []


def test_watchdog_legacy_direct_call_still_works():
    # tests/test_callbacks.py-style direct on_step_end invocation (the
    # base class aliases it to on_step)
    wd = StragglerWatchdog(factor=3.0, warmup=1)
    for t in (0.1, 0.1, 0.1, 9.0):
        rec = {"time_s": t}
        wd.on_step_end(None, rec)
    assert rec.get("straggler") and wd.n_flagged == 1


def test_default_callbacks_arm_escalation():
    mem = _FakeMembership()
    spec = tiny_spec(elastic=ElasticConfig(evict_after=4))
    wd = default_callbacks(spec, membership=mem)[0]
    assert isinstance(wd, StragglerWatchdog)
    assert wd.evict_after == 4 and wd.membership is mem


# ------------------------------------------------------------ ONN cache
def test_onn_runtime_cache_warm_across_shrink():
    """Re-deriving the topology for a previously-seen N1 is a cache HIT:
    the (2,2) warmup resolves N=4 and N1=2 modules; shrinking to (1,2)
    needs only N=2 — already resolved."""
    from repro.api import build
    from repro.photonics import PhotonicsConfig, runtime

    spec = tiny_spec(mesh=MeshSpec(dp=2, pods=2),
                     sync=SyncConfig(mode="cascade", bits=2,
                                     photonics=PhotonicsConfig(
                                         fidelity="onn")),
                     elastic=ElasticConfig(allow_reshard=True))
    build.warmup_photonics(spec)
    before = dict(runtime._CACHE)
    m_before = runtime.get_module(spec.sync.photonics, 2, 2)
    shrunk = dataclasses.replace(
        spec, mesh=dataclasses.replace(spec.mesh, pods=1))
    build.warmup_photonics(shrunk)
    assert dict(runtime._CACHE) == before          # no new modules built
    assert runtime.get_module(spec.sync.photonics, 2, 2) is m_before


# ------------------------------------------------------------ wire model
def test_modeled_wire_shrinks_with_topology():
    from repro.api import build
    base = tiny_spec(mesh=MeshSpec(dp=2, pods=2),
                     sync=SyncConfig(mode="cascade"),
                     elastic=ElasticConfig(allow_reshard=True))
    shrunk = dataclasses.replace(
        base, mesh=dataclasses.replace(base.mesh, pods=1))
    b_full = build.modeled_bytes_on_wire(base)
    b_one = build.modeled_bytes_on_wire(shrunk)
    assert 0 < b_one < b_full       # dropping the carry link sheds bytes
    # the degenerate (single-pod) cascade prices exactly like optinc
    opt = dataclasses.replace(shrunk, sync=SyncConfig(mode="optinc"))
    assert b_one == build.modeled_bytes_on_wire(opt)
    assert build.modeled_time_on_wire(shrunk) == \
        build.modeled_time_on_wire(opt)
