"""Property-based tests for the photonics subsystem (hypothesis).

``hypothesis`` is a real optional dependency: this whole module skips
cleanly when it is absent (the container image) and runs for real in CI,
replacing the deterministic miniature stub that used to live in
conftest.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the real hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.photonics import approx, encoding as enc, mesh, mzi  # noqa: E402


# ------------------------- PAM4 encoding properties -------------------------

@settings(max_examples=50, deadline=None)
@given(bits=st.integers(2, 16), v=st.integers(0, 2 ** 16 - 2))
def test_pam4_roundtrip_property(bits, v):
    v = v % (2 ** bits - 1)
    sym = enc.pam4_encode(jnp.asarray([v]), bits)
    assert int(enc.pam4_decode(sym)[0]) == v


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64))
def test_quantize_error_bound(vals):
    g = jnp.asarray(vals, jnp.float32)
    spec = enc.QuantSpec(bits=8, block=0)
    u, s = enc.quantize(g, spec)
    gd = enc.dequantize(u, s, spec)
    # quantization error bounded by half an LSB step
    step = float(s[0]) / spec.levels
    assert float(jnp.max(jnp.abs(g - gd))) <= 0.5 * step + 1e-6


# ----------------------- Givens programming round-trip ----------------------

@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 24), seed=st.integers(0, 2 ** 31 - 1))
def test_givens_decompose_reconstruct_roundtrip(m, seed):
    """decompose -> reconstruct is the identity on random orthogonals,
    and the jax mesh emulator agrees with the numpy oracle."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    prog = mzi.givens_decompose(q)
    assert len(prog.rotations) <= m * (m - 1) // 2
    np.testing.assert_allclose(mzi.reconstruct(prog), q, atol=1e-9)
    emu = np.asarray(mesh.MZIMesh.compile(prog).matrix(), np.float64)
    np.testing.assert_allclose(emu, q, atol=1e-4)  # f32 emulator default


# -------------------- mesh backend equivalence (pallas) ---------------------

@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 24), batch=st.integers(1, 9),
       transpose=st.booleans(), seed=st.integers(0, 2 ** 31 - 1))
def test_mesh_backends_agree_property(m, batch, transpose, seed):
    """pallas(interpret) == xla scan == numpy oracle for random programs
    across widths, batch sizes, and transpose — the three executors of a
    compiled phase program may never drift apart."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    emu = mesh.MZIMesh.compile(mzi.givens_decompose(q))
    x = rng.normal(size=(batch, m)).astype(np.float32)
    oracle = x @ (q if transpose else q.T)
    xla = np.asarray(emu.apply(jnp.asarray(x), transpose=transpose))
    pallas = np.asarray(emu.apply(jnp.asarray(x), transpose=transpose,
                                  backend="pallas"))
    np.testing.assert_allclose(pallas, xla, atol=1e-6)
    np.testing.assert_allclose(pallas, oracle, atol=1e-4)  # f32 default


# ----------------- block-grid kernel vs vmapped xla scan --------------------

@settings(max_examples=20, deadline=None)
@given(blocks=st.integers(1, 5), m=st.integers(2, 20),
       batch=st.integers(1, 19), blocked_x=st.booleans(),
       seed=st.integers(0, 2 ** 31 - 1))
def test_block_grid_kernel_matches_vmapped_xla_property(blocks, m, batch,
                                                        blocked_x, seed):
    """ONE pallas launch with the block axis folded into the grid must be
    bit-exact against the vmapped per-block xla scan across block counts,
    widths, and ragged batch sizes (blk_b=8 forces several partially
    filled batch tiles), for both shared and per-block inputs."""
    rng = np.random.default_rng(seed)

    def one():
        q, _ = np.linalg.qr(rng.normal(size=(m, m)))
        return mesh.MZIMesh.compile(mzi.givens_decompose(q))

    stacked = mesh._stack_meshes([one() for _ in range(blocks)])
    shape = (batch, blocks, m) if blocked_x else (batch, m)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    got = mesh._apply_stacked(stacked, x, blocked_x, backend="pallas",
                              blk_b=8)
    want = mesh._apply_stacked(stacked, x, blocked_x, backend="xla")
    assert got.shape == want.shape == (batch, blocks, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------- matrix-approximation projection ------------------------

_SHAPES = st.sampled_from(
    [(8, 8), (16, 16), (24, 8), (32, 8), (8, 24), (8, 32), (16, 4), (4, 16)])


@settings(max_examples=25, deadline=None)
@given(shape=_SHAPES, seed=st.integers(0, 2 ** 31 - 1))
def test_approx_projection_idempotent(shape, seed):
    """approx_matrix is a projection: applying it twice == once, and it
    never increases the distance to the original (Procrustes)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    wa = approx.approx_matrix(w)
    wa2 = approx.approx_matrix(wa)
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wa2), atol=1e-4)
    assert float(jnp.linalg.norm(w - wa)) <= float(jnp.linalg.norm(w)) + 1e-5
