"""ONN training (hardware-aware, both constraint modes) + MZI mapping."""
import numpy as np
import pytest

from repro.photonics import dataset, onn, training
from repro.photonics import ONNConfig

TINY = ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                 bits=4, n_servers=2, k_inputs=2)


def test_dataset_sizes_match_paper_formula():
    cfg = ONNConfig(structure=(4,), approx_layers=(), bits=8, n_servers=4,
                    k_inputs=4)
    # (N(4^g - 1) + 1)^K with g=1: (4*3+1)^4 = 13^4
    assert dataset.dataset_size(cfg) == 13 ** 4
    cfg16 = ONNConfig(structure=(4,), approx_layers=(), bits=16, n_servers=4,
                      k_inputs=4)
    # g=2: (4*15+1)^4 = 61^4
    assert dataset.dataset_size(cfg16) == 61 ** 4


def test_server_side_dataset_consistent_with_grid():
    rng = np.random.default_rng(0)
    cfg = ONNConfig(structure=(4,), approx_layers=(), bits=8, n_servers=4,
                    k_inputs=4)
    a, t = dataset.server_side_dataset(cfg, rng, 200)
    from repro.photonics import encoding as enc
    out = np.asarray(enc.oracle_from_preprocessed(a, 8, 4))
    np.testing.assert_array_equal(out, t)


@pytest.mark.parametrize("mode", ["project", "cayley"])
def test_training_reaches_full_accuracy_tiny(mode):
    a, t = dataset.full_dataset(TINY)
    tc = training.TrainConfig(epochs=3000, e1=2500, lr=1e-2, mode=mode,
                              proj_every=200)
    params, hist = training.train(TINY, tc, a, t, eval_every=200,
                                  target_acc=1.0)
    acc = training.accuracy(params, a, t, TINY)
    # paper: 100%. cayley (constraint-exact) reaches it; the paper's
    # periodic-projection algorithm carries projection error at this tiny
    # budget, so it gets a slightly looser bar.
    floor = 0.98 if mode == "cayley" else 0.93
    assert acc >= floor, acc
    # hardware structure enforced on the approximated layers
    from repro.photonics import approx
    for idx, layer in enumerate(params, start=1):
        if idx in TINY.approx_layers:
            assert approx.approx_error(layer["w"]) < 1e-4


def test_two_stage_loss_switches():
    a, t = dataset.full_dataset(TINY)
    tc = training.TrainConfig(epochs=4, e1=2, lr=1e-3)
    _, hist = training.train(TINY, tc, a, t)
    assert [h["stage"] for h in hist] == [1, 1, 2, 2]


def test_hardware_mapping_matches_software():
    """Givens-programmed MZI meshes reproduce the trained network function."""
    a, t = dataset.full_dataset(TINY)
    tc = training.TrainConfig(epochs=300, e1=300, lr=1e-2)
    params, _ = training.train(TINY, tc, a, t)
    hw = onn.map_to_hardware(params, TINY)
    sw = np.asarray(training.apply_onn(params, a[:64], TINY))
    hwout = onn.apply_hardware(hw, a[:64], TINY)
    np.testing.assert_allclose(hwout, sw, atol=1e-3)


def test_error_histogram_keys_are_ints():
    a, t = dataset.full_dataset(TINY)
    params = onn.init_params(TINY, __import__("jax").random.PRNGKey(0))
    errs = training.error_histogram(params, a, t, TINY)
    assert all(isinstance(k, int) for k in errs)
