"""repro.photonics subsystem: jittable MZI mesh emulator vs the numpy
oracle, the optinc fidelity cascade, package layout (no import cycles,
core/ shims), and Pallas interpret auto-detection."""
import inspect
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.photonics import (MZIMesh, ONNConfig, ONNModule, PhotonicsConfig,
                             encoding, mesh, mzi, onn, resolve_interpret,
                             runtime)

TINY = ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                 bits=4, n_servers=2, k_inputs=2)


# ------------------------- mesh emulator vs oracle -------------------------

@pytest.mark.parametrize("m", [2, 5, 16, 64])
def test_mesh_matches_reconstruct(m):
    rng = np.random.default_rng(m)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    prog = mzi.givens_decompose(q)
    emu = MZIMesh.compile(prog)
    assert emu.num_rotations == len(prog.rotations)
    np.testing.assert_allclose(np.asarray(emu.matrix(), np.float64), q,
                               atol=1e-4)
    x = rng.normal(size=(7, m)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(emu.apply(jnp.asarray(x))),
                               x @ q.T, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(emu.apply(jnp.asarray(x), transpose=True)), x @ q,
        atol=1e-4)


def test_mesh_apply_hardware_matches_numpy_oracle():
    """Jitted f32 emulator vs the numpy apply_hardware oracle on the full
    TINY ONN (SVD + approximated layers, ReLU, scales)."""
    params = onn.project_approx(onn.init_params(TINY, jax.random.PRNGKey(0)),
                                TINY)
    hw = onn.map_to_hardware(params, TINY)
    progs = mesh.compile_hardware(hw)
    rng = np.random.default_rng(1)
    a = rng.uniform(0, TINY.in_scale, size=(64, 2)).astype(np.float32)
    want = onn.apply_hardware(hw, a, TINY)
    fwd = jax.jit(lambda x: mesh.apply_hardware(progs, x, TINY))
    np.testing.assert_allclose(np.asarray(fwd(jnp.asarray(a))), want,
                               atol=1e-3)
    # vmap-able: per-sample vmap equals the batched call
    vm = jax.vmap(lambda x: mesh.apply_hardware(progs, x, TINY))
    np.testing.assert_allclose(np.asarray(vm(jnp.asarray(a))),
                               np.asarray(fwd(jnp.asarray(a))), atol=1e-5)


ORACLE_X64 = textwrap.dedent("""
    import json
    import jax, numpy as np, jax.numpy as jnp
    from repro.photonics import mesh, mzi, onn
    from repro.photonics.onn import ONNConfig

    CFGS = [
        ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                  bits=4, n_servers=2, k_inputs=2),
        ONNConfig(structure=(4, 32, 64, 32, 4), approx_layers=(),
                  bits=8, n_servers=4, k_inputs=4),
        ONNConfig(structure=(1, 4, 1), approx_layers=(), bits=2,
                  n_servers=3, k_inputs=1),
    ]
    diffs = []
    for i, cfg in enumerate(CFGS):
        params = onn.project_approx(
            onn.init_params(cfg, jax.random.PRNGKey(i)), cfg)
        hw = onn.map_to_hardware(params, cfg)
        progs = mesh.compile_hardware(hw)          # float64 under x64
        a = np.random.default_rng(i).uniform(
            0, cfg.in_scale, size=(32, cfg.structure[0]))
        want = onn.apply_hardware(hw, a, cfg)
        got = np.asarray(jax.jit(
            lambda x: mesh.apply_hardware(progs, x, cfg))(jnp.asarray(a)))
        diffs.append(float(np.abs(got - want).max()))
    print(json.dumps(diffs))
""")


def test_mesh_oracle_parity_1e6_x64():
    """Acceptance bar: the emulator matches the numpy oracle to <= 1e-6 on
    every ONNConfig structure the suite uses (x64 so float noise cannot
    mask a math error; the compile default follows jax_enable_x64)."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", ORACLE_X64],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(JAX_ENABLE_X64="1"))
    assert r.returncode == 0, r.stderr[-2000:]
    diffs = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(d <= 1e-6 for d in diffs), diffs


# ----------------------- exact identity ONN = oracle -----------------------

def test_exact_identity_module_is_oracle():
    """All 27 three-server code combinations at bits=2: the built-in exact
    ONN reproduces Q(mean) through BOTH the dense and the mesh path."""
    module = ONNModule.exact_identity(bits=2, n_servers=3)
    codes = np.stack(np.meshgrid(*([np.arange(3)] * 3),
                                 indexing="ij")).reshape(3, -1)
    sym = encoding.pam4_encode(jnp.asarray(codes), 2)
    a = encoding.preprocess(sym, 2, module.cfg.k_inputs)
    want = np.asarray(encoding.expected_avg_symbols(sym, 2))
    np.testing.assert_array_equal(
        np.asarray(module.symbols(a, fidelity="onn")), want)
    np.testing.assert_array_equal(
        np.asarray(module.symbols(a, fidelity="mesh")), want)


def test_exact_identity_requires_single_symbol():
    with pytest.raises(ValueError):
        ONNModule.exact_identity(bits=8, n_servers=4)


# --------------------- fidelity cascade in the collective -------------------

FIDELITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.collectives import SyncConfig, sync_gradients
    from repro.photonics import PhotonicsConfig
    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(0)
    # odd N: random data (the unit-P average can never tie at x.5);
    # even N: identical per-device gradients (code sums divisible by N),
    # so the even-N path is exercised without decision-threshold ties
    cases = {
        "n3": (make_mesh((3,), ("data",)),
               rng.normal(size=(3, 4096)).astype(np.float32)),
        "n4": (make_mesh((4,), ("data",)),
               np.tile(rng.normal(size=(1, 4096)).astype(np.float32),
                       (4, 1))),
    }

    def run(mesh, g, fidelity, mesh_backend="xla"):
        ph = PhotonicsConfig(fidelity=fidelity, mesh_backend=mesh_backend)
        sync = SyncConfig(mode="optinc", axes=("data",), bits=2, block=512,
                          error_feedback=True, photonics=ph)
        def f(x):
            out, res = sync_gradients([x], sync, None,
                                      jnp.zeros((x.size,), jnp.float32))
            return out[0], res
        fn = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P("data"),
            out_specs=(P("data"), P("data")), check_vma=False))
        out, res = fn(jnp.asarray(g.reshape(-1)))
        return np.asarray(out), np.asarray(res)

    results = {}
    for name, (mesh, g) in cases.items():
        beh, beh_res = run(mesh, g, "behavioral")
        for fid, backend in (("onn", "xla"), ("mesh", "xla"),
                             ("mesh", "pallas")):
            out, res = run(mesh, g, fid, backend)
            results[f"{name}.{fid}.{backend}"] = [
                float(np.abs(out - beh).max()),
                float(np.abs(res - beh_res).max())]
    print(json.dumps(results))
""")


def test_fidelity_mesh_reproduces_behavioral_multidevice():
    """Acceptance bar: a jit-compiled fidelity='mesh' (and 'onn')
    sync_gradients step on a 100%-accuracy ONN reproduces the behavioral
    backend's averaged gradient (and error-feedback residual) bit-exactly
    — on a 3-device mesh with random gradients and a 4-device mesh with
    tie-free gradients (exactness is only claimed away from the PAM4
    decision threshold; see EXPERIMENTS.md §Mesh emulation).  The mesh
    fidelity is gated through BOTH executors (xla scan and the fused
    pallas kernel, interpret mode off-TPU)."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", FIDELITY_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    results = json.loads(r.stdout.strip().splitlines()[-1])
    for key, diffs in results.items():
        assert diffs == [0.0, 0.0], (key, results)


def test_cascade_backend_still_validates_axes():
    """Photonic fidelities are legal for cascade now (the pipeline runs
    both levels through the emulator — tests/test_pipeline.py), and a
    SINGLE-axis cascade degrades to one-level optinc (elastic shrink to
    one pod — tests/test_elastic.py asserts bit-exactness), but a
    cascade with NO axes stays rejected."""
    from repro.collectives import get_backend, SyncConfig
    cfg = SyncConfig(mode="cascade", axes=(),
                     photonics=PhotonicsConfig(fidelity="mesh"))
    with pytest.raises(ValueError, match=">= 2 mesh axes"):
        get_backend("cascade").sync(jnp.zeros((8,)), cfg, None)


# ------------------------------ runtime resolution --------------------------

def test_runtime_resolves_exact_and_caches():
    ph = PhotonicsConfig(fidelity="mesh")
    m1 = runtime.get_module(ph, 2, 3)
    assert m1.cfg.structure == (1, 4, 1)
    assert m1._programs is not None          # mesh fidelity precompiles
    assert runtime.get_module(ph, 2, 3) is m1


def test_runtime_refuses_untrained_wide_bits(monkeypatch):
    # hermetic: a results/scenario1*_params.pkl produced by quickstart
    # --scenario1 (e.g. the nightly trained-ONN job, or a local run) must
    # not turn this into a successful resolution
    monkeypatch.setattr(runtime, "RESULTS_PICKLES",
                        ("results/_absent_for_test.pkl",))
    with pytest.raises(ValueError, match="no trained params"):
        runtime._build(PhotonicsConfig(fidelity="onn"), 8, 4)


def test_runtime_cache_ignores_executor_and_tuning_knobs():
    """mesh_backend / blk_b / noise stds select how a resolved module is
    APPLIED, not what is built: sweeping them (xla-vs-pallas comparisons,
    --blk-b-sweep, noise on/off) must hit ONE cached build instead of
    re-running Givens programming per knob value."""
    import dataclasses
    ph = PhotonicsConfig(fidelity="mesh")
    base = runtime.get_module(ph, 2, 3)
    for variant in (dataclasses.replace(ph, mesh_backend="pallas"),
                    dataclasses.replace(ph, blk_b=64),
                    dataclasses.replace(ph, mesh_backend="pallas",
                                        blk_b=256),
                    dataclasses.replace(ph, theta_drift_std=0.02,
                                        shot_noise_std=0.01)):
        assert runtime.get_module(variant, 2, 3) is base


def test_runtime_put_module_overrides():
    ph = PhotonicsConfig(fidelity="onn", k_inputs=1)
    module = ONNModule.exact_identity(2, 5)
    runtime.put_module(ph, 2, 5, module)
    assert runtime.get_module(ph, 2, 5) is module


# ------------------------- package layout / import order --------------------

def test_no_import_cycle_onn_first():
    """Importing repro.photonics.onn FIRST (fresh interpreter) must work:
    the encoding dependency is a clean module-level import now."""
    from conftest import subprocess_env
    code = ("import repro.photonics.onn as o; "
            "print(o.ONNConfig(structure=(4,), bits=8, n_servers=4, "
            "k_inputs=4).in_scale)")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip() == "3.0"  # g = ceil(M/K) = 1 -> 4^1 - 1
    # and the historical function-local workaround is really gone
    src = inspect.getsource(ONNConfig.in_scale.fget)
    assert "import" not in src


def test_no_import_cycle_cascade_first():
    """repro.photonics.cascade imports clean in a fresh interpreter, and
    the repro.core.cascade shim re-exports it WITHOUT tripping
    DeprecationWarning-as-error (the PR-5 migration satellite)."""
    from conftest import subprocess_env
    code = ("import repro.photonics.cascade as c; "
            "print(c.extra_symbols(16))")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip() == "2"
    code = ("import warnings; "
            "warnings.simplefilter('error', DeprecationWarning); "
            "from repro.core.cascade import carry_cascade; "
            "import numpy as np; "
            "print(int(carry_cascade(np.ones((2, 2, 3), np.int64))[0]))")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip() == "1"


def test_core_shims_alias_photonics():
    """core/ re-export shims expose the same objects, not copies."""
    from repro.core import approx as c_approx
    from repro.core import cascade as c_cascade
    from repro.core import encoding as c_enc
    from repro.core import mzi as c_mzi
    from repro.core import onn as c_onn
    from repro.core import training as c_training
    from repro.photonics import approx as p_approx, training as p_training
    from repro.photonics import cascade as p_cascade
    assert c_onn.ONNConfig is ONNConfig
    assert c_enc.pam4_encode is encoding.pam4_encode
    assert c_mzi.givens_decompose is mzi.givens_decompose
    assert c_approx.approx_matrix is p_approx.approx_matrix
    assert c_training.train is p_training.train
    assert c_cascade.carry_cascade is p_cascade.carry_cascade
    assert c_cascade.CascadeConfig is p_cascade.CascadeConfig
    assert c_cascade.extra_symbols is p_cascade.extra_symbols


# ----------------------- spec threading of the fidelity knob ----------------

def test_runspec_fidelity_flag_and_roundtrip():
    from repro.api import RunSpec, SpecError
    spec = RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                              "--fidelity", "mesh"])
    assert spec.sync.photonics.fidelity == "mesh"
    assert RunSpec.from_json(spec.to_json()) == spec
    with pytest.raises(SpecError, match="photonic-backend knob"):
        RunSpec.from_args(["--sync", "ring", "--fidelity", "mesh"])
    # a bad fidelity in a --spec file is a SpecError, not a raw ValueError
    with pytest.raises(SpecError, match="invalid PhotonicsConfig"):
        RunSpec.from_json_dict({"sync": {"photonics": {"fidelity": "bogus"}}})


# -------------------- Pallas interpret auto-detection -----------------------

def test_resolve_interpret():
    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_kernel_auto_interpret_agrees():
    """The auto-detected path and the explicit interpret=True path must
    produce identical results (on TPU this pits the compiled kernel
    against the interpreter; off-TPU both interpret — either way the
    kernels must agree with the jnp reference)."""
    from repro.kernels import pam4 as pam4_k
    from repro.kernels import onn_layer as onn_k
    from repro.kernels import ref
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    scale = jnp.max(jnp.abs(g), axis=1)
    auto = pam4_k.pam4_quantize_encode(g, scale, 8)
    forced = pam4_k.pam4_quantize_encode(g, scale, 8, interpret=True)
    want = ref.pam4_quantize_encode_ref(g, scale, 8, 256)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(want))

    x = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    q, _ = np.linalg.qr(rng.normal(size=(128, 128)))
    u = jnp.asarray(q.astype(np.float32))
    d = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    y_auto = onn_k.onn_layer(x, u, d, b)
    y_forced = onn_k.onn_layer(x, u, d, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_forced))
    np.testing.assert_allclose(np.asarray(y_auto),
                               np.asarray(ref.onn_layer_ref(x, u, d, b)),
                               rtol=1e-4, atol=1e-4)
