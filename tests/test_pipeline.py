"""Photonic sync pipeline: stage-composable optinc/cascade levels, the
eq.-10 carry symbol through Encode/Readout, cascade photonic fidelity
bit-exactness on a (2,2) pod x data mesh, and the PhaseNoise model
(thermal drift + shot noise, key-seeded determinism, std=0 exactness)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.photonics import (MZIMesh, ONNModule, PhaseNoise, PhotonicsConfig,
                             encoding, mesh, mzi, pipeline)


# ------------------------- stage-level carry semantics -------------------------

def test_readout_encode_carry_round_trip():
    """Readout(emit_carry) reads the eq.-10 decimal part off the ANALOG
    symbols; decoded + frac reproduces the analog value exactly, and the
    next level's Encode merges frac into the least-significant group."""
    module = ONNModule.exact_identity(bits=2, n_servers=2)
    ro = pipeline.Readout(transceiver=module.transceiver, emit_carry=True)
    analog = jnp.asarray(np.float32([[0.0], [0.5], [1.5], [2.0], [1.25]]))
    out = ro.apply(pipeline.Carry(analog), None)
    decoded = encoding.pam4_decode(out.data).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(decoded + out.frac),
                                  np.asarray(analog[..., 0]))
    # Encode consumes the carry: grouped value == integer code + frac
    enc = pipeline.Encode(bits=2, k_inputs=1)
    dec = pipeline.Decode().apply(out, None)
    merged = enc.apply(dec, None)
    np.testing.assert_array_equal(np.asarray(merged.data[..., 0]),
                                  np.asarray(analog[..., 0]))


def test_level_pipeline_single_device_is_oracle():
    """One pipeline level with no sync axes == the ONN transfer function:
    Q(identity mean) of the codes, for both fidelities."""
    module = ONNModule.exact_identity(bits=2, n_servers=1)
    u = jnp.asarray(np.arange(3, dtype=np.int32))
    for fid in ("onn", "mesh"):
        pipe = pipeline.level_pipeline(module, 2, (), fidelity=fid)
        out = jax.jit(lambda x: pipe.run(x).data)(u)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(u))


# ------------------- cascade photonic fidelity, (2,2) mesh -------------------

CASCADE_FIDELITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.collectives import SyncConfig, sync_gradients
    from repro.photonics import PhotonicsConfig
    from repro.photonics.cascade import carry_cascade, expected
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2), ("pod", "data"))
    rng = np.random.default_rng(0)
    M = 2048
    # RANDOM gradients: PAM4 decision ties (sum(u) % 4 == 2) occur and
    # must resolve exactly like the behavioral round-half-even — the
    # wire-exact identity ONN guarantees it (module.exact_identity)
    g = rng.normal(size=(4, M)).astype(np.float32)
    g[:, :256] = 0.0          # zero-block guard on-mesh

    def run(fidelity, mesh_backend="xla"):
        ph = PhotonicsConfig(fidelity=fidelity, mesh_backend=mesh_backend)
        sync = SyncConfig(mode="cascade", axes=("pod", "data"), bits=2,
                          block=256, error_feedback=True, photonics=ph)
        def f(x):
            out, res = sync_gradients([x], sync, None,
                                      jnp.zeros((x.size,), jnp.float32))
            return out, res
        fn = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P(("pod", "data")),
            out_specs=(P(("pod", "data")), P(("pod", "data"))),
            check_vma=False))
        out, res = fn(jnp.asarray(g.reshape(-1)))
        return np.asarray(out[0]), np.asarray(res)

    beh, beh_res = run("behavioral")
    results = {}
    for fid, backend in (("onn", "xla"), ("mesh", "xla"),
                         ("mesh", "pallas")):
        out, res = run(fid, backend)
        results[f"{fid}.{backend}"] = [float(np.abs(out - beh).max()),
                                       float(np.abs(res - beh_res).max())]

    # and the behavioral cascade itself still equals eq. 10 == eq. 8
    from repro.photonics.encoding import QuantSpec, quantize
    spec = QuantSpec(bits=2, block=256)
    scale = np.abs(g.reshape(4, -1, 256)).max(axis=(0, 2))
    us = [np.asarray(quantize(jnp.asarray(g[i]), spec,
                              scale=jnp.asarray(np.maximum(scale, 1e-38)))[0])
          for i in range(4)]
    u = np.stack(us).reshape(2, 2, M)
    results["eq10_eq8"] = int((carry_cascade(u) != expected(u)).sum())
    print(json.dumps(results))
""")


def test_cascade_photonic_bitexact_2x2():
    """Acceptance bar: --sync cascade at fidelity onn/mesh (xla AND the
    fused pallas kernel) is bit-exact against the behavioral carry-cascade
    on a (2,2) pod x data mesh with RANDOM gradients — decision ties
    included — plus identical error-feedback residuals."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", CASCADE_FIDELITY_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    results = json.loads(r.stdout.strip().splitlines()[-1])
    assert results.pop("eq10_eq8") == 0
    for key, diffs in results.items():
        assert diffs == [0.0, 0.0], (key, results)


CASCADE_TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, io, contextlib
    import repro.launch.train as T

    def run(fidelity):
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            T.main(["--arch", "minitron_4b", "--smoke-config",
                    "--sync", "cascade", "--mesh", "2x1", "--steps", "3",
                    "--global-batch", "4", "--seq-len", "32",
                    "--lr", "1e-3", "--bits", "2", "--fidelity", fidelity])
        return [json.loads(l)["loss"] for l in buf.getvalue().splitlines()
                if l.startswith("{")]

    print(json.dumps({"behavioral": run("behavioral"),
                      "mesh": run("mesh")}))
""")


@pytest.mark.slow
def test_cascade_train_mesh_fidelity_losses_identical():
    """Tier-1 acceptance gate: ``train.py --sync cascade --fidelity mesh``
    on a (2,2) pod x data mesh trains to losses IDENTICAL to
    ``--fidelity behavioral`` (100%-accuracy built-in ONN at bits=2,
    zero noise) — both cascade levels run the MZI mesh emulator inside
    every jitted step."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", CASCADE_TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    losses = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(losses["behavioral"]) == 3
    assert losses["mesh"] == losses["behavioral"], losses


# ------------------------------ PhaseNoise model ------------------------------

def _compiled_mesh(m=16, seed=3):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    return (mesh.MZIMesh.compile(mzi.givens_decompose(q)),
            jnp.asarray(rng.normal(size=(4, m)).astype(np.float32)))


def test_phase_noise_std0_bitexact_both_executors():
    """std=0 disables each noise term STATICALLY: apply with a zero
    PhaseNoise + key is bit-identical to the noise-free path on the xla
    scan AND the pallas kernel (the PR-4 parity rows stay untouched)."""
    emu, x = _compiled_mesh()
    zero = PhaseNoise(0.0, 0.0)
    key = jax.random.PRNGKey(0)
    assert not zero.enabled
    assert PhaseNoise.from_config(PhotonicsConfig(fidelity="mesh")) is None
    for backend in ("xla", "pallas"):
        plain = emu.apply(x, backend=backend)
        gated = emu.apply(x, backend=backend, noise=zero, key=key)
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(gated))


def test_phase_noise_is_coherent_and_key_deterministic():
    """Theta drift perturbs each MZI's two wires coherently (layers stay
    rotations, the perturbed matrix stays orthogonal), is reproducible
    under one key, and differs across keys; shot noise perturbs outputs."""
    emu, x = _compiled_mesh()
    noise = PhaseNoise(theta_drift_std=0.05, shot_noise_std=0.0)
    key = jax.random.PRNGKey(42)
    perm = jnp.asarray(emu.perm)
    ca1, sa1 = noise.perturb(key, perm, jnp.asarray(emu.ca),
                             jnp.asarray(emu.sa))
    ca2, _ = noise.perturb(key, perm, jnp.asarray(emu.ca),
                           jnp.asarray(emu.sa))
    np.testing.assert_array_equal(np.asarray(ca1), np.asarray(ca2))
    # each layer row still satisfies ca^2 + sa^2 == 1 (pure rotations)
    r = np.asarray(ca1) ** 2 + np.asarray(sa1) ** 2
    np.testing.assert_allclose(r, 1.0, atol=1e-6)

    y0 = emu.apply(x)
    yn = emu.apply(x, noise=noise, key=key)
    assert float(jnp.abs(yn - y0).max()) > 0.0
    np.testing.assert_array_equal(
        np.asarray(yn), np.asarray(emu.apply(x, noise=noise, key=key)))
    assert not np.array_equal(
        np.asarray(yn),
        np.asarray(emu.apply(x, noise=noise, key=jax.random.PRNGKey(43))))
    # drifted mesh is still orthogonal: drift models phase error, not loss
    mat = np.asarray(emu.apply(jnp.eye(emu.dim), noise=noise, key=key)).T
    np.testing.assert_allclose(mat @ mat.T, np.eye(emu.dim), atol=1e-5)

    shot = PhaseNoise(0.0, 0.01)
    ys = emu.apply(x, noise=shot, key=key)
    assert float(jnp.abs(ys - y0).max()) > 0.0


NOISE_PROCESS_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.api import RunSpec
    from repro.photonics import PhaseNoise, get_module

    spec = RunSpec.from_args(["--sync", "optinc", "--bits", "2",
                              "--fidelity", "mesh",
                              "--theta-drift-std", "0.05",
                              "--shot-noise-std", "0.01", "--seed", "7"])
    ph = spec.sync.photonics
    module = get_module(ph, spec.sync.bits, 4)
    noise = PhaseNoise.from_config(ph)
    key = jax.random.fold_in(jax.random.PRNGKey(spec.seed + 1), 5)  # step 5
    prog = module.programs[0].u
    ca, sa = noise.perturb(key, jnp.asarray(prog.perm),
                           jnp.asarray(prog.ca), jnp.asarray(prog.sa))
    out = module.apply_mesh(jnp.asarray(np.float32([[0.5], [1.25]])),
                            noise=noise, key=key)
    print(json.dumps({"ca": np.asarray(ca).tolist(),
                      "sa": np.asarray(sa).tolist(),
                      "out": np.asarray(out).tolist()}))
""")


@pytest.mark.slow
def test_phase_noise_identical_across_processes():
    """Same RunSpec + same step key => identical perturbed thetas (and
    mesh outputs) in two separate processes — noise draws come from the
    per-step key only, never from process-local state."""
    from conftest import subprocess_env

    def once():
        r = subprocess.run([sys.executable, "-c", NOISE_PROCESS_SCRIPT],
                           capture_output=True, text=True, timeout=600,
                           env=subprocess_env())
        assert r.returncode == 0, r.stderr[-2000:]
        return json.loads(r.stdout.strip().splitlines()[-1])

    a, b = once(), once()
    assert a == b


def test_noise_requires_step_key():
    """A noisy PhotonicsConfig without a per-step sync key would silently
    train noise-free; the backend rejects it at trace time."""
    from repro.collectives import SyncConfig, get_backend
    ph = PhotonicsConfig(fidelity="mesh", theta_drift_std=0.1)
    cfg = SyncConfig(mode="optinc", axes=(), bits=2, photonics=ph)
    with pytest.raises(ValueError, match="per-step sync key"):
        get_backend("optinc").sync(jnp.zeros((8,)), cfg, None)


# ----------------------- spec threading of the new knobs -----------------------

def test_runspec_noise_and_cascade_fidelity_flags():
    from repro.api import RunSpec, SpecError
    spec = RunSpec.from_args(["--sync", "cascade", "--bits", "2",
                              "--fidelity", "mesh",
                              "--theta-drift-std", "0.02",
                              "--shot-noise-std", "0.01"])
    assert spec.sync.photonics.fidelity == "mesh"
    assert spec.sync.photonics.theta_drift_std == 0.02
    assert spec.sync.photonics.shot_noise_std == 0.01
    assert spec.mesh.pods == 2            # cascade auto-provisions pods
    assert RunSpec.from_json(spec.to_json()) == spec
    # cascade now accepts the photonic fidelities; ring/psum still do not
    with pytest.raises(SpecError, match="photonic-backend knob"):
        RunSpec.from_args(["--sync", "ring", "--fidelity", "mesh"])
    # noise models the emulated mesh only
    with pytest.raises(SpecError, match="fidelity mesh"):
        RunSpec.from_args(["--sync", "optinc", "--fidelity", "onn",
                           "--theta-drift-std", "0.1"])
    # the photonic cascade is single-symbol-only until cascade-trained
    # ONNs exist (the carry must stay on the unit-P grid)
    with pytest.raises(SpecError, match="bits <= 2"):
        RunSpec.from_args(["--sync", "cascade", "--bits", "8",
                           "--fidelity", "mesh"])
    with pytest.raises(SpecError, match="error-feedback"):
        RunSpec.from_args(["--sync", "optinc", "--sparse-residuals"])
    # negative stds are a config error (wrapped as SpecError from JSON)
    with pytest.raises(SpecError, match="invalid PhotonicsConfig"):
        RunSpec.from_json_dict(
            {"sync": {"photonics": {"theta_drift_std": -0.1}}})
