"""Cascading topology (paper III-C): eq. 9 loses, eq. 10 is exact."""
import numpy as np

from repro.core import cascade


def test_carry_cascade_exact_eq10():
    rng = np.random.default_rng(0)
    for n in (2, 4):
        u = rng.integers(0, 255, size=(n, n, 5000))
        exp = cascade.expected(u)
        np.testing.assert_array_equal(cascade.carry_cascade(u), exp)


def test_basic_cascade_loses_decimals():
    rng = np.random.default_rng(1)
    u = rng.integers(0, 255, size=(4, 4, 5000))
    exp = cascade.expected(u)
    bas = cascade.basic_cascade(u)
    frac_wrong = (bas != exp).mean()
    assert 0.01 < frac_wrong < 0.5  # two-level quantization visibly wrong
    assert np.max(np.abs(bas - exp)) <= 1  # but only off-by-one


def test_extra_symbols():
    assert cascade.extra_symbols(4) == 1   # resolution 1/4 -> 1 PAM4 symbol
    assert cascade.extra_symbols(16) == 2
    assert cascade.extra_symbols(2) == 1


def test_cascade_hardware_overhead_close_to_paper():
    # paper: ~10.5% for scenario 1 expanded with two 64x64 approx matrices
    ov = cascade.hardware_overhead((4, 64, 128, 256, 128, 64, 4),
                                   tuple(range(1, 7)))
    assert 0.05 < ov < 0.15, ov
