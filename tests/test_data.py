"""Data pipeline determinism (fault-tolerance contract)."""
import numpy as np

from repro.data import DataConfig, SyntheticLM


def test_deterministic_by_step():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    a = SyntheticLM(cfg).batch(12)
    b = SyntheticLM(cfg).batch(12)  # fresh instance = restarted worker
    np.testing.assert_array_equal(a, b)
    c = SyntheticLM(cfg).batch(13)
    assert not np.array_equal(a, c)


def test_shards_disjoint_streams():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    s0 = SyntheticLM(cfg, shard=0, num_shards=2).batch(5)
    s1 = SyntheticLM(cfg, shard=1, num_shards=2).batch(5)
    assert s0.shape == (4, 65)
    assert not np.array_equal(s0, s1)


def test_tokens_in_vocab():
    cfg = DataConfig(vocab=321, seq_len=32, global_batch=4)
    b = SyntheticLM(cfg).batch(0)
    assert b.min() >= 0 and b.max() < 321


def test_bigram_structure_learnable():
    """The deterministic bigram component must be present (conditional
    entropy visibly below unigram entropy)."""
    cfg = DataConfig(vocab=50, seq_len=2000, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    ds = SyntheticLM(cfg)
    follows = sum(int(b[i, t] == ds.shift[b[i, t - 1]])
                  for i in range(2) for t in range(1, 2001))
    assert follows / (2 * 2000) > 0.3
