"""Bucketizer: flatten/unflatten round-trip exactness over mixed
shape/dtype pytrees, and the launch-budget arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collectives import (bucketize, expected_buckets, make_layout,
                               tree_bucketize, tree_unbucketize, unbucketize)


def _mixed_tree():
    rng = np.random.default_rng(0)
    return {
        "emb": jnp.asarray(rng.normal(size=(17, 8)), jnp.float32),
        "blocks": [jnp.asarray(rng.normal(size=(3, 5, 2)), jnp.bfloat16),
                   jnp.asarray(rng.normal(size=(33,)), jnp.float16)],
        "scalarish": jnp.asarray(rng.normal(size=(1,)), jnp.float32),
        "norm": jnp.asarray(rng.normal(size=(64,)), jnp.bfloat16),
    }


@pytest.mark.parametrize("bucket_bytes", [16, 64, 4096, 4 * 2 ** 20])
def test_roundtrip_exact_mixed_tree(bucket_bytes):
    tree = _mixed_tree()
    buckets, aux = tree_bucketize(tree, bucket_bytes)
    back = tree_unbucketize(buckets, aux)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert bool((a == b).all())  # f32 holds bf16/f16 losslessly


def test_bucket_count_and_bounds():
    leaves = [jnp.zeros((1000,)), jnp.zeros((24,))]
    total = 1024
    layout = make_layout(leaves, bucket_bytes=256)  # 64 f32 elems/bucket
    assert layout.total == total
    assert layout.n_buckets == expected_buckets(total * 4, 256) == 16
    # buckets tile the concat space exactly, in order
    assert layout.bounds[0] == (0, 64)
    assert layout.bounds[-1] == (total - 64, total)
    spans = [e - s for s, e in layout.bounds]
    assert sum(spans) == total


def test_short_final_bucket():
    leaves = [jnp.arange(10, dtype=jnp.float32)]
    layout = make_layout(leaves, bucket_bytes=16)  # 4 elems/bucket
    assert layout.n_buckets == 3
    assert layout.bounds[-1] == (8, 10)
    buckets = bucketize(leaves, layout)
    assert buckets[-1].shape == (2,)
    (back,) = unbucketize(buckets, layout)
    assert bool((back == leaves[0]).all())


def test_buckets_span_leaf_boundaries():
    # one bucket fuses many small leaves: shared-scale fusion across leaf
    # boundaries requires the concat ordering to be stable tree order
    leaves = [jnp.full((3,), float(i)) for i in range(5)]
    layout = make_layout(leaves, bucket_bytes=4 * 2 ** 20)
    assert layout.n_buckets == 1
    (bucket,) = bucketize(leaves, layout)
    want = np.repeat(np.arange(5, dtype=np.float32), 3)
    np.testing.assert_array_equal(np.asarray(bucket), want)


def test_empty_tree():
    buckets, aux = tree_bucketize({}, 4096)
    assert buckets == []
    assert tree_unbucketize(buckets, aux) == {}
