"""Bucketizer: flatten/unflatten round-trip exactness over mixed
shape/dtype pytrees, the launch-budget arithmetic, and the streaming
engine's segment/launch-order maps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collectives import (bucketize, expected_buckets, make_layout,
                               tree_bucketize, tree_unbucketize, unbucketize)
from repro.collectives.bucketizer import (bucket_segments, launch_order,
                                          leaf_segments)


def _mixed_tree():
    rng = np.random.default_rng(0)
    return {
        "emb": jnp.asarray(rng.normal(size=(17, 8)), jnp.float32),
        "blocks": [jnp.asarray(rng.normal(size=(3, 5, 2)), jnp.bfloat16),
                   jnp.asarray(rng.normal(size=(33,)), jnp.float16)],
        "scalarish": jnp.asarray(rng.normal(size=(1,)), jnp.float32),
        "norm": jnp.asarray(rng.normal(size=(64,)), jnp.bfloat16),
    }


@pytest.mark.parametrize("bucket_bytes", [16, 64, 4096, 4 * 2 ** 20])
def test_roundtrip_exact_mixed_tree(bucket_bytes):
    tree = _mixed_tree()
    buckets, aux = tree_bucketize(tree, bucket_bytes)
    back = tree_unbucketize(buckets, aux)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert bool((a == b).all())  # f32 holds bf16/f16 losslessly


def test_bucket_count_and_bounds():
    leaves = [jnp.zeros((1000,)), jnp.zeros((24,))]
    total = 1024
    layout = make_layout(leaves, bucket_bytes=256)  # 64 f32 elems/bucket
    assert layout.total == total
    assert layout.n_buckets == expected_buckets(total * 4, 256) == 16
    # buckets tile the concat space exactly, in order
    assert layout.bounds[0] == (0, 64)
    assert layout.bounds[-1] == (total - 64, total)
    spans = [e - s for s, e in layout.bounds]
    assert sum(spans) == total


def test_short_final_bucket():
    leaves = [jnp.arange(10, dtype=jnp.float32)]
    layout = make_layout(leaves, bucket_bytes=16)  # 4 elems/bucket
    assert layout.n_buckets == 3
    assert layout.bounds[-1] == (8, 10)
    buckets = bucketize(leaves, layout)
    assert buckets[-1].shape == (2,)
    (back,) = unbucketize(buckets, layout)
    assert bool((back == leaves[0]).all())


def test_buckets_span_leaf_boundaries():
    # one bucket fuses many small leaves: shared-scale fusion across leaf
    # boundaries requires the concat ordering to be stable tree order
    leaves = [jnp.full((3,), float(i)) for i in range(5)]
    layout = make_layout(leaves, bucket_bytes=4 * 2 ** 20)
    assert layout.n_buckets == 1
    (bucket,) = bucketize(leaves, layout)
    want = np.repeat(np.arange(5, dtype=np.float32), 3)
    np.testing.assert_array_equal(np.asarray(bucket), want)


def test_empty_tree():
    buckets, aux = tree_bucketize({}, 4096)
    assert buckets == []
    assert tree_unbucketize(buckets, aux) == {}


# ------------------------------- edge cases --------------------------------

def test_single_bucket_larger_than_model():
    """bucket_bytes >> total size: exactly ONE bucket covering everything,
    no empty ragged tail."""
    leaves = [jnp.arange(10, dtype=jnp.float32), jnp.ones((3,), jnp.float32)]
    layout = make_layout(leaves, bucket_bytes=64 * 2 ** 20)
    assert layout.n_buckets == 1
    assert layout.bounds == ((0, 13),)
    assert all(e > s for s, e in layout.bounds)  # never a zero-size bucket
    buckets = bucketize(leaves, layout)
    assert len(buckets) == 1 and buckets[0].shape == (13,)
    back = unbucketize(buckets, layout)
    assert bool((back[0] == leaves[0]).all())
    assert bool((back[1] == leaves[1]).all())


def test_single_leaf_tree_layout():
    leaves = [jnp.arange(100, dtype=jnp.float32)]
    for bb in (64, 400, 4096):  # smaller, exact, larger than the leaf
        layout = make_layout(leaves, bucket_bytes=bb)
        assert all(e > s for s, e in layout.bounds)
        assert layout.bounds[-1][1] == 100
        (back,) = unbucketize(bucketize(leaves, layout), layout)
        assert bool((back == leaves[0]).all())
        # segments of a single leaf tile it exactly, in order
        segs = leaf_segments(layout)[0]
        assert [b for b, _, _ in segs] == list(range(layout.n_buckets))


def test_exact_multiple_no_empty_tail():
    """total a multiple of the bucket size: the last bucket is full, not
    followed by an empty one."""
    leaves = [jnp.zeros((128,), jnp.float32)]
    layout = make_layout(leaves, bucket_bytes=256)  # 64 elems -> 2 buckets
    assert layout.n_buckets == 2
    assert layout.bounds == ((0, 64), (64, 128))


def test_zero_size_leaf_in_no_bucket():
    leaves = [jnp.zeros((5,), jnp.float32), jnp.zeros((0,), jnp.float32),
              jnp.zeros((7,), jnp.float32)]
    layout = make_layout(leaves, bucket_bytes=16)
    segs = bucket_segments(layout)
    assert all(i != 1 for seg in segs for i, _, _ in seg)
    assert leaf_segments(layout)[1] == ()
    back = unbucketize(bucketize(leaves, layout), layout)
    assert back[1].shape == (0,)


# ------------------------ streaming segment maps ---------------------------

def test_bucket_segments_tile_bounds():
    leaves = [jnp.zeros((600,)), jnp.zeros((300,)), jnp.zeros((77,))]
    layout = make_layout(leaves, bucket_bytes=1024)  # 256-elem buckets
    offsets = np.cumsum([0] + [int(l.size) for l in leaves])[:-1]
    for b, seg in enumerate(bucket_segments(layout)):
        s, e = layout.bounds[b]
        covered = sorted((offsets[i] + a, offsets[i] + t)
                         for i, a, t in seg)
        # leaf-local slices, translated to concat space, tile [s, e)
        assert covered[0][0] == s and covered[-1][1] == e
        for (_, hi), (lo, _) in zip(covered, covered[1:]):
            assert hi == lo


def test_leaf_segments_is_transpose():
    leaves = [jnp.zeros((600,)), jnp.zeros((300,)), jnp.zeros((77,))]
    layout = make_layout(leaves, bucket_bytes=1024)
    pairs_a = {(i, b) for b, seg in enumerate(bucket_segments(layout))
               for i, _, _ in seg}
    pairs_b = {(i, b) for i, segs in enumerate(leaf_segments(layout))
               for b, _, _ in segs}
    assert pairs_a == pairs_b
    # per-leaf pieces cover each leaf exactly
    for i, segs in enumerate(leaf_segments(layout)):
        assert sum(e - s for _, s, e in segs) == layout.sizes[i]


def test_launch_order_default_is_reversed_buckets():
    leaves = [jnp.zeros((600,)), jnp.zeros((300,)), jnp.zeros((77,))]
    layout = make_layout(leaves, bucket_bytes=1024)
    order = launch_order(layout)
    assert order == tuple(reversed(range(layout.n_buckets)))


def test_launch_order_custom_readiness_and_validation():
    leaves = [jnp.zeros((64,)), jnp.zeros((64,))]
    layout = make_layout(leaves, bucket_bytes=256)  # one bucket per leaf
    # forward-emission readiness: tree order is launch order
    assert launch_order(layout, readiness=(0, 1)) == (0, 1)
    assert launch_order(layout, readiness=(1, 0)) == (1, 0)
    with pytest.raises(ValueError):
        launch_order(layout, readiness=(0,))
