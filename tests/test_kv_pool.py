"""repro.serving.kv_pool: allocator invariants, pool layout, and
paged-vs-contiguous cache-content equality (the paged pool must hold
exactly the bytes the contiguous decode cache would)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (ShardCtx, paged_gather, paged_update_cache,
                                 update_cache)
from repro.serving import (NULL_PAGE, PageAllocator, ServeConfig, init_pool,
                           pool_specs, supports_paged, write_prompt,
                           write_prompts)

# --------------------------------------------------------------- allocator


def test_allocator_never_hands_out_null_page():
    a = PageAllocator(16)
    got = a.alloc(15)
    assert got is not None and len(got) == 15
    assert NULL_PAGE not in got
    assert len(set(got)) == 15
    assert a.free_pages == 0


def test_allocator_exhaustion_is_all_or_nothing():
    a = PageAllocator(8)           # 7 usable pages
    assert a.alloc(4) is not None
    before = a.free_pages
    assert a.alloc(5) is None      # too big: must NOT leak a partial grab
    assert a.free_pages == before == 3
    assert a.alloc(3) is not None


def test_allocator_free_then_reuse():
    a = PageAllocator(4)
    first = a.alloc(3)
    assert a.alloc(1) is None
    a.free(first[:2])
    second = a.alloc(2)
    assert second is not None and set(second) == set(first[:2])


def test_allocator_double_free_raises():
    a = PageAllocator(4)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(ValueError, match="not allocated"):
        a.free(got)
    with pytest.raises(ValueError, match="not allocated"):
        a.free([NULL_PAGE])
    with pytest.raises(ValueError, match="null page"):
        PageAllocator(1)


def test_scheduler_preemption_requeues_at_front():
    from repro.serving import Scheduler
    cfg = ServeConfig(page_size=4, max_active=2, max_seq=16, pages=6)
    sched = Scheduler(cfg, PageAllocator(cfg.auto_pages()))
    r0 = sched.submit([1] * 8, 4)   # 2 pages
    r1 = sched.submit([2] * 8, 4)   # 2 pages -> 1 of 5 usable pages left
    admitted = sched.admit()
    assert [s.req.rid for s in admitted] == [r0, r1]
    # r1 (youngest) gets evicted when someone must grow
    victim = sched.preempt_youngest()
    assert victim.req.rid == r1 and sched.n_preempted == 1
    assert sched.queue[0].rid == r1          # front of the queue
    assert sched.alloc.free_pages == 3       # its pages came back
    # generated tokens survive preemption: re-admission prefills them too
    victim.req.generated.extend([7, 8])
    (readmitted,) = sched.admit()
    assert readmitted.req.rid == r1
    assert readmitted.length == 10           # prompt 8 + generated 2


def test_scheduler_rejects_oversized_and_overflowing():
    from repro.serving import QueueFull, Scheduler
    cfg = ServeConfig(page_size=4, max_active=1, max_seq=8, max_queue=2)
    sched = Scheduler(cfg, PageAllocator(cfg.auto_pages()))
    with pytest.raises(ValueError, match="capacity"):
        sched.submit([1] * 8, 4)    # 8 + 4 > capacity 8
    with pytest.raises(ValueError, match="empty"):
        sched.submit([], 4)
    sched.submit([1, 2], 2)
    sched.submit([1, 2], 2)
    with pytest.raises(QueueFull):
        sched.submit([1, 2], 2)


# ------------------------------------------------------------ pool layout
def _cfg(arch="minitron_4b"):
    from repro import configs
    return configs.get_smoke(arch)


def test_pool_shapes_and_specs_align():
    cfg = _cfg()
    ctx = ShardCtx()
    pool = init_pool(cfg, ctx, n_pages=6, page_size=4)
    k = pool["layers"]["k"]
    assert k.shape[0] == cfg.n_layers and k.shape[1] == 6
    assert k.shape[3] == 4 and k.shape[4] == cfg.hd
    specs = pool_specs(ctx)
    assert jax.tree.structure(specs) == jax.tree.structure(pool)
    assert supports_paged(cfg)
    assert not supports_paged(_cfg("zamba2_7b"))
    assert not supports_paged(_cfg("whisper_tiny"))
    assert not supports_paged(_cfg("phi35_moe_42b"))


# ----------------------------------------- paged == contiguous, bit for bit
def test_write_prompt_matches_contiguous_prefix():
    cfg = _cfg()
    ctx = ShardCtx()
    ps, t = 4, 10
    rng = np.random.default_rng(0)
    kvl = 2
    pre = {"layers": {
        "k": jnp.asarray(rng.normal(size=(cfg.n_layers, 1, kvl, t, cfg.hd)),
                         jnp.float32),
        "v": jnp.asarray(rng.normal(size=(cfg.n_layers, 1, kvl, t, cfg.hd)),
                         jnp.float32)}}
    pool = {"layers": {
        "k": jnp.zeros((cfg.n_layers, 8, kvl, ps, cfg.hd), jnp.float32),
        "v": jnp.zeros((cfg.n_layers, 8, kvl, ps, cfg.hd), jnp.float32)}}
    pages = jnp.asarray([3, 5, 1], jnp.int32)   # deliberately out of order
    pool = write_prompt(pool, pre, pages)
    for leaf in ("k", "v"):
        # gather layer 0's pages back as one contiguous view
        got = paged_gather(pool["layers"][leaf][0], jnp.asarray([[3, 5, 1]]))
        np.testing.assert_array_equal(np.asarray(got[0, :, :t]),
                                      np.asarray(pre["layers"][leaf][0, 0]))
        # the tail of the last page stays zero (masked as invalid)
        assert np.all(np.asarray(got[0, :, t:]) == 0)
        # the null page was never written
        assert np.all(np.asarray(pool["layers"][leaf][:, NULL_PAGE]) == 0)


def test_paged_decode_write_matches_update_cache():
    """One decode step's K written through the paged path equals the
    contiguous update_cache write, gathered back in sequence order."""
    ctx = ShardCtx()
    rng = np.random.default_rng(1)
    b, kvl, ps, hd, nb = 3, 2, 4, 8, 3
    lengths = np.asarray([5, 0, 9])             # mid-page, start, last slot
    new = jnp.asarray(rng.normal(size=(b, kvl, 1, hd)), jnp.float32)
    # contiguous reference: each row written at its own position
    contig = jnp.zeros((b, kvl, nb * ps, hd), jnp.float32)
    refs = [update_cache(contig[i:i + 1], new[i:i + 1], int(lengths[i]), ctx)
            for i in range(b)]
    # paged: per-row page table, one shared physical pool
    pool = jnp.zeros((1 + b * nb, kvl, ps, hd), jnp.float32)
    table = np.arange(1, 1 + b * nb, dtype=np.int32).reshape(b, nb)
    page_ids = jnp.asarray(
        [table[i, lengths[i] // ps] for i in range(b)], jnp.int32)
    pool = paged_update_cache(pool, new, page_ids,
                              jnp.asarray(lengths % ps, jnp.int32))
    got = paged_gather(pool, jnp.asarray(table))
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(refs[i][0]))


def test_paged_write_crosses_page_boundary():
    """Writes at length % page_size == 0 land at offset 0 of the NEXT
    logical block — the freshly allocated page a just-grown sequence
    decodes into — and gathering back still matches the contiguous
    update_cache write."""
    ctx = ShardCtx()
    rng = np.random.default_rng(5)
    b, kvl, ps, hd, nb = 3, 2, 4, 8, 3
    lengths = np.asarray([4, 8, 3])   # page-exact x2, plus a mid-page row
    new = jnp.asarray(rng.normal(size=(b, kvl, 1, hd)), jnp.float32)
    contig = jnp.zeros((b, kvl, nb * ps, hd), jnp.float32)
    refs = [update_cache(contig[i:i + 1], new[i:i + 1], int(lengths[i]), ctx)
            for i in range(b)]
    pool = jnp.zeros((1 + b * nb, kvl, ps, hd), jnp.float32)
    table = np.arange(1, 1 + b * nb, dtype=np.int32).reshape(b, nb)
    page_ids = jnp.asarray(
        [table[i, lengths[i] // ps] for i in range(b)], jnp.int32)
    pool = paged_update_cache(pool, new, page_ids,
                              jnp.asarray(lengths % ps, jnp.int32))
    got = paged_gather(pool, jnp.asarray(table))
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(refs[i][0]))
        # the boundary write touched exactly one position in one page
        touched = np.asarray(pool[table[i]]).transpose(1, 0, 2, 3).reshape(
            kvl, nb * ps, hd)
        assert (np.abs(touched).sum(axis=(0, 2)) > 0).sum() == 1
    # previous pages (the pages BEFORE the boundary) stay untouched zeros
    assert np.all(np.asarray(pool[table[0, 0]]) == 0)   # row 0 wrote page 1
    assert np.all(np.asarray(pool[table[1, :2]]) == 0)  # row 1 wrote page 2


def test_write_prompts_matches_per_row_write_prompt():
    """The batched prefill scatter equals per-row write_prompt for every
    live row, drops pad-token KV past each row's length, writes nothing
    for length-0 pad rows, and leaves the null page all-zero even though
    pad rows and unallocated blocks scatter into it."""
    cfg = _cfg()
    ctx = ShardCtx()
    ps, tb = 4, 12                     # bucket = 3 blocks
    kvl, hd, n_pages = 2, cfg.hd, 12
    rng = np.random.default_rng(6)
    lengths = np.asarray([5, 12, 0], np.int32)   # partial, full, pad row
    b = len(lengths)
    pre = {"layers": {
        leaf: jnp.asarray(
            rng.normal(size=(cfg.n_layers, b, kvl, tb, hd)), jnp.float32)
        for leaf in ("k", "v")}}
    tables = np.zeros((b, tb // ps), np.int32)
    tables[0, :2] = [3, 5]
    tables[1, :3] = [1, 7, 2]
    pool0 = {"layers": {
        leaf: jnp.zeros((cfg.n_layers, n_pages, kvl, ps, hd), jnp.float32)
        for leaf in ("k", "v")}}
    got = write_prompts(pool0, pre, jnp.asarray(tables),
                        jnp.asarray(lengths))
    # reference: per-row write_prompt over the row's valid prefix
    ref = pool0
    for i in range(2):                 # live rows only
        t, used = int(lengths[i]), -(-int(lengths[i]) // ps)
        row = jax.tree.map(lambda kv: kv[:, i:i + 1, :, :t], pre)
        ref = write_prompt(ref, row, jnp.asarray(tables[i, :used]))
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(got["layers"][leaf]),
                                      np.asarray(ref["layers"][leaf]))
        assert np.all(np.asarray(got["layers"][leaf][:, NULL_PAGE]) == 0)


def test_decode_attention_vector_positions_match_scalar():
    """decode_attention with per-slot (b,) position counts equals running
    each row separately with its scalar position — the property the
    packed continuous batch relies on."""
    from repro.models.layers import decode_attention
    ctx = ShardCtx()
    rng = np.random.default_rng(2)
    b, h, hkv, s, hd = 4, 4, 2, 12, 8
    q = jnp.asarray(rng.normal(size=(b, h, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, hd)), jnp.float32)
    pos = np.asarray([3, 12, 1, 7])
    batched = decode_attention(ctx, q, k, v, jnp.asarray(pos))
    for i in range(b):
        single = decode_attention(ctx, q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                  int(pos[i]))
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(single[0]))
    # stale garbage beyond a row's length contributes exactly nothing
    k_dirty = k.at[0, :, 3:].set(1e4)
    v_dirty = v.at[0, :, 3:].set(-1e4)
    dirty = decode_attention(ctx, q, k_dirty, v_dirty, jnp.asarray(pos))
    np.testing.assert_array_equal(np.asarray(dirty[0]),
                                  np.asarray(batched[0]))
