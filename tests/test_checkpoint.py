"""Checkpoint/restart: atomicity, corrupt-skip, elastic restore."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import latest_step


def _tree():
    k = jax.random.PRNGKey(0)
    return {"layers": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,))},
            "embed": jax.random.normal(k, (32, 8))}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    assert latest_step(tmp_path) == 7
    got, man = load_checkpoint(tmp_path, 7, {"params": t})
    for a, b in zip(jax.tree.leaves(got["params"]), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert man["step"] == 7


def test_corrupt_checkpoint_skipped(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    # corrupt the newest
    (tmp_path / "step_2" / "manifest.json").write_text("{broken")
    assert latest_step(tmp_path) == 1


def test_tmp_dir_never_counts(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    (tmp_path / "step_9.tmp").mkdir()
    assert latest_step(tmp_path) == 3


def test_manager_keeps_last_k(tmp_path):
    t = _tree()
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps[-1] == 4 and len(steps) <= 3


def test_elastic_restore_resharded(tmp_path):
    """Save unsharded, restore onto a 1x1 mesh with explicit specs."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    t = _tree()
    save_checkpoint(tmp_path, 5, t)
    mesh = make_mesh((1, 1), ("data", "model"))
    specs = {"params": {"layers": {"w": P(None, "model"), "b": P(None)},
                        "embed": P("model", None)}}
    got, _ = load_checkpoint(tmp_path, 5, {"params": t}, mesh=mesh,
                             specs=specs)
    np.testing.assert_array_equal(np.asarray(got["params"]["embed"]),
                                  np.asarray(t["embed"]))
