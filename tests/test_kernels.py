"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention as attn_k
from repro.kernels import onn_layer as onn_k
from repro.kernels import pam4 as pam4_k
from repro.kernels import ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("bits", [4, 8, 16])
@pytest.mark.parametrize("shape", [(8, 128), (32, 256), (16, 1024)])
def test_pam4_encode_kernel(bits, shape):
    g = jnp.asarray(RNG.normal(size=shape).astype(np.float32))
    scale = jnp.max(jnp.abs(g), axis=1)
    u = pam4_k.pam4_quantize_encode(g, scale, bits)
    u_ref = ref.pam4_quantize_encode_ref(g, scale, bits, shape[1])
    np.testing.assert_array_equal(np.asarray(u), np.asarray(u_ref))


@pytest.mark.parametrize("n", [2, 4, 16])
@pytest.mark.parametrize("bits", [4, 8])
def test_pam4_decode_kernel(n, bits):
    shape = (16, 256)
    levels = 2 ** (bits - 1) - 1
    total = jnp.asarray(
        RNG.integers(0, n * 2 * levels, size=shape).astype(np.int32))
    scale = jnp.asarray(RNG.uniform(0.5, 2.0, shape[0]).astype(np.float32))
    out = pam4_k.pam4_decode_dequantize(total, scale, bits, n)
    want = ref.pam4_decode_dequantize_ref(ref.pam4_qmean_ref(total, n),
                                          scale, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("bsz,m,n", [(128, 128, 128), (256, 128, 256),
                                     (128, 256, 384), (384, 512, 128)])
@pytest.mark.parametrize("relu", [True, False])
def test_onn_layer_kernel(bsz, m, n, relu):
    x = jnp.asarray(RNG.normal(size=(bsz, n)).astype(np.float32))
    q, _ = np.linalg.qr(RNG.normal(size=(max(m, n), max(m, n))))
    u = jnp.asarray(q[:m, :n].astype(np.float32))
    d = jnp.asarray(RNG.normal(size=(m,)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(m,)).astype(np.float32))
    y = onn_k.onn_layer(x, u, d, b, relu=relu)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.onn_layer_ref(x, u, d, b, relu)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("sq,skv,d,causal", [
    (256, 256, 64, True), (128, 512, 64, True), (256, 256, 128, False),
    (512, 512, 64, True)])
def test_flash_attention_kernel(sq, skv, d, causal):
    q = jnp.asarray(RNG.normal(size=(sq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(skv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(skv, d)).astype(np.float32))
    o = attn_k.flash_attention(q, k, v, causal=causal)
    o_ref = ref.mha_ref(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(o - o_ref))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jnp.asarray(RNG.normal(size=(128, 64))).astype(dtype)
    k = jnp.asarray(RNG.normal(size=(128, 64))).astype(dtype)
    v = jnp.asarray(RNG.normal(size=(128, 64))).astype(dtype)
    o = attn_k.flash_attention(q, k, v)
    o_ref = ref.mha_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                 - o_ref.astype(jnp.float32)))) < tol


def test_blocked_attention_matches_kernel_math():
    """The model-side jnp blocked attention is the same math as the Pallas
    kernel (they must agree to float tolerance)."""
    from repro.models.layers import blocked_attention
    q = jnp.asarray(RNG.normal(size=(1, 4, 256, 64)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)).astype(np.float32))
    a = blocked_attention(q, k, v, causal=True, blk_q=64, blk_kv=64)
    kk = jnp.repeat(k, 2, 1)
    vv = jnp.repeat(v, 2, 1)
    b = jax.vmap(jax.vmap(lambda q, k, v: attn_k.flash_attention(
        q, k, v, causal=True, blk_q=64, blk_k=64)))(q, kk, vv)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
