"""TrainSession callback stack: StragglerWatchdog semantics."""
from repro.api.callbacks import StragglerWatchdog


def _feed(wd, times):
    records = []
    for t in times:
        rec = {"step": len(records), "time_s": t}
        wd.on_step_end(None, rec)
        records.append(rec)
    return records


def test_watchdog_flags_threshold_trip():
    wd = StragglerWatchdog(factor=3.0, window=50, warmup=3)
    recs = _feed(wd, [1.0] * 5 + [10.0])
    assert all("straggler" not in r for r in recs[:5])
    assert recs[-1].get("straggler") is True
    assert wd.n_flagged == 1


def test_watchdog_resets_on_progress():
    """One straggler must not poison the rolling median: subsequent normal
    steps come back clean."""
    wd = StragglerWatchdog(factor=3.0, window=50, warmup=3)
    recs = _feed(wd, [1.0] * 5 + [10.0] + [1.0] * 5)
    assert recs[5].get("straggler") is True
    assert all("straggler" not in r for r in recs[6:])
    assert wd.n_flagged == 1


def test_watchdog_warmup_suppresses_early_flags():
    wd = StragglerWatchdog(factor=3.0, window=50, warmup=10)
    recs = _feed(wd, [1.0, 1.0, 50.0])
    assert all("straggler" not in r for r in recs)
    assert wd.n_flagged == 0


def test_watchdog_disabled_is_noop():
    for factor in (0.0, -1.0):
        wd = StragglerWatchdog(factor=factor, window=50, warmup=0)
        recs = _feed(wd, [1.0, 1.0, 1.0, 1000.0])
        assert not wd.enabled
        assert all("straggler" not in r for r in recs)
        assert wd.times == []  # no history kept at all
        assert wd.n_flagged == 0
