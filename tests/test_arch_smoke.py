"""Per-arch smoke tests: reduced config, one train step + one decode step
on CPU, asserting output shapes and no NaNs (full configs are exercised
only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.collectives import SyncConfig
from repro.launch import steps
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init

MESH = make_mesh((1, 1), ("data", "model"))
SYNC = SyncConfig(mode="optinc", axes=("data",), bits=8, block=1024)
OPT = AdamWConfig(lr=1e-3)


def _batch(cfg, b=2, t=33):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)))}
    if cfg.enc_dec:
        batch["enc_frames"] = jnp.full((b, cfg.enc_frames, cfg.d_model),
                                       0.1, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    ctx = steps.make_ctx(MESH)
    params = lm.init_params(cfg, ctx, jax.random.PRNGKey(0))
    opt_state = adamw_init(OPT, params)
    fn, _, _ = steps.make_train_step(cfg, MESH, SYNC, OPT)
    with jax.set_mesh(MESH):
        p2, o2, _, m = jax.jit(fn)(params, opt_state, {}, _batch(cfg),
                                   jax.random.PRNGKey(1))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed (total movement across all leaves; single
    # bf16 norm leaves can legitimately round to no change)
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_prefill_and_decode_smoke(arch):
    cfg = configs.get_smoke(arch)
    ctx = steps.make_ctx(MESH)
    params = lm.init_params(cfg, ctx, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, t=32)
    pre, _, _ = steps.make_prefill_step(cfg, MESH)
    dec, _, _ = steps.make_decode_step(cfg, MESH)
    with jax.set_mesh(MESH):
        logits, _ = jax.jit(pre)(params, batch)
        cache = lm.init_cache(cfg, ctx, 2, 64)
        lg, cache2 = jax.jit(dec)(params, cache,
                                  batch["tokens"][:, :1], jnp.int32(0))
        lg2, _ = jax.jit(dec)(params, cache2,
                              batch["tokens"][:, 1:2], jnp.int32(1))
    v_pad = lm.pad_to(cfg.vocab, 1)
    assert logits.shape == (2, v_pad)
    assert lg.shape == (2, v_pad)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(lg).all()) and bool(jnp.isfinite(lg2).all())


def test_decode_matches_forward_dense():
    """Step-by-step decode must reproduce the prefill logits at the last
    position (dense arch; validates cache correctness)."""
    cfg = configs.get_smoke("minitron_4b")
    ctx = steps.make_ctx(MESH)
    params = lm.init_params(cfg, ctx, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9)))
    pre, _, _ = steps.make_prefill_step(cfg, MESH)
    dec, _, _ = steps.make_decode_step(cfg, MESH)
    with jax.set_mesh(MESH):
        want, _ = jax.jit(pre)(params, {"tokens": toks})
        cache = lm.init_cache(cfg, ctx, 1, 16)
        for i in range(9):
            got, cache = jax.jit(dec)(params, cache, toks[:, i:i + 1],
                                      jnp.int32(i))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_all_archs_have_configs_and_cells():
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        cells = configs.cells(arch)
        assert set(cells) == set(configs.SHAPES)
        skips = [n for n, c in cells.items() if "skip" in c]
        if cfg.ssm in ("mamba2", "xlstm"):
            assert not skips        # sub-quadratic archs run everything
        else:
            assert skips == ["long_500k"]
