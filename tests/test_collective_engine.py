"""Bucket-fused collective engine: backend registry, zero-gradient guard,
cascade-vs-carry_cascade parity on a 2x2 pod x data mesh, error-feedback
residual carry across train steps, and the O(buckets) launch budget."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.collectives import (SyncConfig, available_backends,
                               expected_buckets, get_backend,
                               register_backend, sync_gradients)
from repro.launch import steps
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init
from jax.sharding import PartitionSpec as P

MESH = make_mesh((1, 1), ("data", "model"))


def _run_sync(tree, cfg, in_specs, out_specs, mesh=None):
    mesh = mesh or make_mesh((1,), ("data",))

    def f(t):
        out, _ = sync_gradients(t, cfg, None, None)
        return out

    fn = jax.shard_map(f, mesh=mesh, in_specs=(in_specs,),
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)(tree)


# ----------------------------- registry -----------------------------

def test_registry_builtins():
    assert set(available_backends()) >= {"psum", "ring", "optinc", "cascade"}
    for name in ("psum", "ring", "optinc", "cascade"):
        b = get_backend(name)
        assert callable(b.sync) and callable(b.bytes_on_wire)
        assert callable(b.time_on_wire)


def test_registry_rejects_duplicates_and_unknown():
    with pytest.raises(ValueError):
        register_backend("optinc", get_backend("optinc"))
    with pytest.raises(ValueError):
        get_backend("definitely-not-a-backend")


def test_custom_backend_usable_as_sync_mode():
    class Negate:
        def sync(self, flat, cfg, key):
            return -flat, None

        def bytes_on_wire(self, nbytes, n, bits):
            return 0.0

        def time_on_wire(self, nbytes, n, bits, overlap=False,
                         bucket_bytes=0):
            return 0.0

    register_backend("negate-test", Negate(), overwrite=True)
    g = [jnp.arange(8, dtype=jnp.float32)]
    out = _run_sync(g, SyncConfig(mode="negate-test", axes=("data",)),
                    [P()], [P()])
    np.testing.assert_array_equal(np.asarray(out[0]), -np.arange(8))


# --------------------------- zero-grad guard ---------------------------

@pytest.mark.parametrize("mode", ["optinc"])
def test_zero_gradient_blocks_stay_finite(mode):
    """Regression: an all-zero block leaves scale at the f32-tiny floor;
    round(flat / tiny * levels) must not overflow — zero blocks are
    short-circuited to the zero code."""
    g = {"zero": jnp.zeros((4096,), jnp.float32),
         "denormal": jnp.full((512,), 1e-41, jnp.float32),
         "mixed": jnp.concatenate([jnp.zeros((512,), jnp.float32),
                                   jnp.ones((512,), jnp.float32)])}
    cfg = SyncConfig(mode=mode, axes=("data",), bits=8, block=256,
                     bucket_bytes=1024)
    spec = {k: P() for k in g}
    out = _run_sync(g, cfg, spec, spec)
    for k, v in out.items():
        assert bool(jnp.isfinite(v).all()), k
    assert bool((out["zero"] == 0).all())
    # the nonzero half of "mixed" must survive quantization
    assert float(jnp.abs(out["mixed"][512:] - 1.0).max()) < 0.02


# ------------------------ error-feedback carry ------------------------

def test_error_feedback_residual_carries_across_steps():
    cfg = configs.get_smoke("paper_llama")
    sync = SyncConfig(mode="optinc", axes=("data",), bits=4, block=512,
                      error_feedback=True)
    params = lm.init_params(cfg, steps.make_ctx(MESH), jax.random.PRNGKey(0))
    opt_state = adamw_init(AdamWConfig(lr=1e-3), params)
    fn, _, _ = steps.make_train_step(cfg, MESH, sync, AdamWConfig(lr=1e-3))
    state = steps.init_sync_state(cfg, MESH, sync)
    nparams = sum(int(l.size) for l in jax.tree.leaves(params))
    assert state["rep"].shape == (nparams,)  # 1 device, all replicated
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 33)))}
    with jax.set_mesh(MESH):
        jit = jax.jit(fn)
        p1, o1, s1, _ = jit(params, opt_state, state, batch,
                            jax.random.PRNGKey(1))
        p2, o2, s2, _ = jit(p1, o1, s1, batch, jax.random.PRNGKey(2))
    # residuals are real quantization error, not zeros...
    assert float(jnp.abs(s1["rep"]).max()) > 0.0
    # ...and the second step consumed + replaced them
    assert float(jnp.abs(s2["rep"] - s1["rep"]).max()) > 0.0


# --------------------- bucket-scan vs unrolled loop ---------------------

def test_bucket_scan_bitexact_vs_unrolled():
    """Full-size buckets sync under ONE lax.scan (compile-once); the scan
    must be bit-exact against the Python-unrolled per-bucket loop it
    replaced — same per-bucket math, same per-bucket keys, ragged tail
    included."""
    from repro.collectives.bucketizer import (flatten_concat, make_layout,
                                              unbucketize)
    rng = np.random.default_rng(0)
    # 1 KiB buckets over 900 f32 elements: 3 full buckets + a ragged tail
    g = {"a": jnp.asarray(rng.normal(size=(600,)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(300,)).astype(np.float32))}
    cfg = SyncConfig(mode="optinc", axes=("data",), bits=4, block=64,
                     bucket_bytes=1024)
    backend = get_backend("optinc")

    def scanned(t, key):
        out, _ = sync_gradients(t, cfg, key, None)
        return out

    def unrolled(t, key):
        leaves, treedef = jax.tree.flatten(t)
        layout = make_layout(leaves, cfg.bucket_bytes)
        flat = flatten_concat(leaves)
        keys = jax.random.split(key, len(layout.bounds))
        outs = [backend.sync(flat[s:e], cfg, k)[0]
                for (s, e), k in zip(layout.bounds, keys)]
        return jax.tree.unflatten(treedef, unbucketize(outs, layout))

    mesh = make_mesh((1,), ("data",))
    spec = {k: P() for k in g}

    def run(f):
        fn = jax.shard_map(f, mesh=mesh, in_specs=(spec, P()),
                           out_specs=spec, check_vma=False)
        return jax.jit(fn)(g, jax.random.PRNGKey(7))

    got, want = run(scanned), run(unrolled)
    for k in g:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


# ------------------------- launch-count budget -------------------------

def test_optinc_launch_count_is_o_buckets():
    """optinc must issue <= ceil(total_grad_bytes / bucket_bytes)
    reduce-scatter launches per step (counted in the traced jaxpr)."""
    cfg = configs.get_smoke("paper_llama")
    bucket_bytes = 4 * 2 ** 20
    sync = SyncConfig(mode="optinc", axes=("data",), bits=8, block=2048,
                      bucket_bytes=bucket_bytes)
    ctx = steps.make_ctx(MESH)
    p_sds = lm.param_shape_dtype(cfg, ctx)
    nparams = sum(int(s.size) for s in jax.tree.leaves(p_sds))
    fn, _, _ = steps.make_train_step(cfg, MESH, sync, AdamWConfig())
    from repro.api.shapes import batch_sds, opt_sds
    args = (p_sds, opt_sds(p_sds), {}, batch_sds(cfg, 33, 2),
            jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    jaxpr = str(jax.make_jaxpr(fn)(*args))
    budget = expected_buckets(nparams * 4, bucket_bytes)
    # lax.psum_scatter traces as the reduce_scatter primitive; the only
    # all_gathers in this config are the optinc code gathers
    n_rs = jaxpr.count("reduce_scatter[")
    n_ag = jaxpr.count("all_gather[")
    assert 0 < n_rs <= budget, (n_rs, budget)
    assert 0 < n_ag <= budget, (n_ag, budget)


# --------------------- cascade parity (subprocess) ---------------------

CASCADE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.collectives import SyncConfig, sync_gradients
    from repro.core import cascade
    from repro.photonics.encoding import QuantSpec, quantize, dequantize
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2), ("pod", "data"))
    rng = np.random.default_rng(0)
    M = 512
    g = rng.normal(size=(4, M)).astype(np.float32)
    g[:, :128] = 0.0   # an all-zero block exercises the guard on-mesh
    bits, block = 8, 128

    def f(x):
        out, _ = sync_gradients(
            [x], SyncConfig(mode="cascade", axes=("pod", "data"),
                            bits=bits, block=block, bucket_bytes=1024),
            None, None)
        return out[0]

    fn = jax.shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                       out_specs=P(("pod", "data")), check_vma=False)
    got = np.asarray(jax.jit(fn)(jnp.asarray(g.reshape(-1)))).reshape(4, M)

    out = {"identical": float(np.abs(got - got[0]).max())}
    # reference: shared-scale quantize -> carry_cascade (eq. 10) -> deq.
    # bucket_bytes=1024 splits each device's 512-elem shard into 2
    # buckets of 256 elems = 2 blocks, so per-block scales match the
    # unbucketed reference (block boundaries align).
    spec = QuantSpec(bits=bits, block=block)
    scale = np.abs(g.reshape(4, -1, block)).max(axis=(0, 2))
    us = [np.asarray(quantize(jnp.asarray(g[i]), spec,
                              scale=jnp.asarray(np.maximum(scale, 1e-38)))[0])
          for i in range(4)]
    u = np.stack(us).reshape(2, 2, M)           # (pod, data, elems)
    u_avg = cascade.carry_cascade(u)            # == eq. 8 expected()
    assert (u_avg == cascade.expected(u)).all()
    safe = np.where(scale <= 1.1754944e-38, 1.0, scale)
    want = ((u_avg - spec.levels).reshape(-1, block)
            * (safe[:, None] / spec.levels)).reshape(-1).astype(np.float32)
    out["cascade_vs_eq10"] = float(np.abs(got[0] - want).max())
    out["zero_block_exact"] = float(np.abs(got[0][:128]).max())
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_cascade_matches_carry_cascade_2x2():
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", CASCADE_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env())
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["identical"] == 0.0
    assert out["cascade_vs_eq10"] < 1e-6
    assert out["zero_block_exact"] == 0.0
