"""Scaling OptINC to 16 servers by cascading (paper III-C / Fig. 5).

Five scenario-1 OptINCs (N=4 each) in two levels support 16 servers.
Naive cascading double-quantizes (eq. 9) and corrupts ~14% of averaged
gradients; the paper's decimal-carry datasets (eq. 10) make the cascade
exact. This script demonstrates both, plus the ~10% MZI overhead of the
widened cascade ONN.

  PYTHONPATH=src python examples/cascade_16servers.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import area, cascade
from repro.core.cascade import CascadeConfig


def main():
    rng = np.random.default_rng(0)
    # 16 servers as a 4x4 grid of B=8 gradients
    u = rng.integers(0, 255, size=(4, 4, 100_000))

    exact = cascade.expected(u)
    naive = cascade.basic_cascade(u)
    carry = cascade.carry_cascade(u)

    print(f"16-server quantized average over {u.shape[-1]} gradients")
    print(f"  naive two-level cascade (eq. 9): "
          f"{(naive != exact).mean() * 100:.2f}% wrong "
          f"(max abs err {np.abs(naive - exact).max()})")
    print(f"  decimal-carry cascade  (eq. 10): "
          f"{(carry != exact).mean() * 100:.2f}% wrong")
    assert (carry == exact).all()

    cc = CascadeConfig()
    base = (4, 64, 128, 256, 128, 64, 4)
    exp_struct = cc.expanded_structure(base)
    print(f"\nexpanded ONN structure for the carry symbols: {exp_struct}")
    ov = cascade.hardware_overhead(base, tuple(range(1, 7)))
    print(f"MZI overhead vs the base scenario-1 ONN: {ov * 100:.1f}% "
          f"(paper: ~10.5%)")
    print(f"extra PAM4 symbols needed at resolution 1/N: "
          f"{cascade.extra_symbols(4)}")


if __name__ == "__main__":
    main()
