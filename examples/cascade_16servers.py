"""Scaling OptINC to 16 servers by cascading (paper III-C / Fig. 5).

Five scenario-1 OptINCs (N=4 each) in two levels support 16 servers.
Naive cascading double-quantizes (eq. 9) and corrupts ~14% of averaged
gradients; the paper's decimal-carry datasets (eq. 10) make the cascade
exact.

This script runs the REAL runtime `cascade` collective backend on a
16-device (pod=4, data=4) host mesh — the same code path
`launch/train.py --sync cascade` uses — and verifies it against the
numpy reference (`core.cascade.carry_cascade`) and the naive eq. 9
baseline, then reports the ~10% MZI overhead of the widened cascade ONN.

  PYTHONPATH=src python examples/cascade_16servers.py
"""
import os
import sys

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=16").strip()
sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.collectives import SyncConfig, sync_gradients  # noqa: E402
from repro.core import cascade  # noqa: E402
from repro.core.cascade import CascadeConfig  # noqa: E402
from repro.photonics.encoding import QuantSpec, quantize  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def runtime_cascade_demo(n_elems: int = 4096, bits: int = 8,
                         block: int = 512):
    """16 servers as a (pod=4, data=4) mesh running the cascade backend."""
    mesh = make_mesh((4, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    g = rng.normal(size=(16, n_elems)).astype(np.float32)

    def f(x):
        out, _ = sync_gradients(
            [x], SyncConfig(mode="cascade", axes=("pod", "data"),
                            bits=bits, block=block,
                            bucket_bytes=n_elems * 4 // 2),
            None, None)
        return out[0]

    fn = jax.shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                       out_specs=P(("pod", "data")), check_vma=False)
    got = np.asarray(jax.jit(fn)(jnp.asarray(g.reshape(-1))))
    got = got.reshape(16, n_elems)

    # numpy reference: shared-scale quantize -> eq. 8 / 9 / 10
    spec = QuantSpec(bits=bits, block=block)
    scale = np.abs(g.reshape(16, -1, block)).max(axis=(0, 2))
    us = np.stack([
        np.asarray(quantize(jnp.asarray(g[i]), spec,
                            scale=jnp.asarray(scale))[0])
        for i in range(16)])
    u = us.reshape(4, 4, n_elems)
    exact = cascade.expected(u)          # eq. 8  (single quantized average)
    naive = cascade.basic_cascade(u)     # eq. 9  (double quantization)
    carry = cascade.carry_cascade(u)     # eq. 10 (decimal carry)

    deq = ((exact - spec.levels).reshape(-1, block)
           * (scale[:, None] / spec.levels)).reshape(-1)
    print(f"16-server runtime cascade over {n_elems} gradients "
          f"(pod=4 x data=4 host mesh)")
    print(f"  all 16 devices identical:        "
          f"{np.abs(got - got[0]).max():.1e}")
    print(f"  runtime backend vs eq. 8 exact:  "
          f"{np.abs(got[0] - deq).max():.1e}  (dequantization tolerance)")
    print(f"  naive two-level cascade (eq. 9): "
          f"{(naive != exact).mean() * 100:.2f}% wrong "
          f"(max abs err {np.abs(naive - exact).max()})")
    print(f"  decimal-carry cascade  (eq. 10): "
          f"{(carry != exact).mean() * 100:.2f}% wrong")
    assert (carry == exact).all()
    assert np.abs(got - got[0]).max() == 0.0
    assert np.abs(got[0] - deq).max() < 1e-6


def hardware_overhead_demo():
    cc = CascadeConfig()
    base = (4, 64, 128, 256, 128, 64, 4)
    exp_struct = cc.expanded_structure(base)
    print(f"\nexpanded ONN structure for the carry symbols: {exp_struct}")
    ov = cascade.hardware_overhead(base, tuple(range(1, 7)))
    print(f"MZI overhead vs the base scenario-1 ONN: {ov * 100:.1f}% "
          f"(paper: ~10.5%)")
    print(f"extra PAM4 symbols needed at resolution 1/N: "
          f"{cascade.extra_symbols(4)}")


def main():
    runtime_cascade_demo()
    hardware_overhead_demo()


if __name__ == "__main__":
    main()
