"""Quickstart for the ``repro.api`` surface (and the paper's ONN pipeline).

  PYTHONPATH=src python examples/quickstart.py [--steps 3] [--arch minitron_4b]
  PYTHONPATH=src python examples/quickstart.py --onn [--scenario1]

Default mode — the declarative API end-to-end on one small scenario:
1. Describe the whole run as a frozen, JSON-round-trippable RunSpec
   (model x mesh x sync backend x optimizer x data x checkpointing).
2. TrainSession runs a few OptINC-synced training steps (JSONL metrics,
   checkpointing and straggler watchdog are callbacks, not loop code).
3. ServeSession reuses the trained params for greedy decoding through the
   same serving path the dry-run cells lower.

--onn runs the paper's core optical pipeline instead (quantize ->
PAM4-encode -> train the hardware-aware ONN -> program MZI meshes ->
area costs; eq. 2-8, Table I).  --scenario1 uses the paper's first
Table-I scenario (B=8, N=4, 13^4 samples; ~30-50 min on one core).
"""
import argparse
import sys

sys.path.insert(0, "src")


def run_api(args):
    import numpy as np

    from repro.api import (AdamWConfig, DataConfig, RunSpec, ServeSession,
                           SyncConfig, TrainSession)

    spec = RunSpec(
        arch=args.arch, smoke=True, steps=args.steps,
        sync=SyncConfig(mode="optinc", bits=8, block=2048),
        optim=AdamWConfig(lr=1e-3),
        data=DataConfig(vocab=0, seq_len=64, global_batch=4, seed=0))
    print("RunSpec (JSON round-trippable — save it, sweep it, resume it):")
    print(spec.to_json())

    print(f"\n--- TrainSession: {spec.steps} OptINC-synced steps ---")
    session = TrainSession(spec)
    history = session.run()
    print(f"loss {history[0]['loss']} -> {history[-1]['loss']}")

    print("\n--- ServeSession: greedy decode with the trained params ---")
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, session.cfg.vocab, (2, 8))
    gen = ServeSession(spec, params=session.params).generate(
        prompts, gen_len=8, max_seq=32)
    print("generated ids[0]:", np.asarray(gen[0]).tolist())


def run_onn(scenario1: bool, epochs_override: int = 0):
    import numpy as np

    from repro.photonics import area, dataset, encoding, onn, training
    from repro.photonics import ONNConfig

    if scenario1:
        cfg = ONNConfig(structure=(4, 64, 128, 256, 128, 64, 4),
                        approx_layers=(1, 2, 3, 4, 5, 6),
                        bits=8, n_servers=4, k_inputs=4)
        epochs, e1 = 3000, 2400
    else:
        cfg = ONNConfig(structure=(2, 64, 128, 256, 128, 64, 2),
                        approx_layers=(1, 2, 3, 4, 5, 6),
                        bits=4, n_servers=2, k_inputs=2)
        epochs, e1 = 4000, 3200
    if epochs_override:
        # dev/CI plumbing knob: a shortened run exercises the identical
        # pipeline (and still persists params) at reduced accuracy
        epochs, e1 = epochs_override, int(epochs_override * 0.8)

    print(f"scenario: B={cfg.bits} N={cfg.n_servers} structure={cfg.structure}")
    print(f"dataset size (paper formula): {dataset.dataset_size(cfg)}")
    a, t = dataset.full_dataset(cfg)

    # --- step 1-2: server-side encoding demo ---
    rng = np.random.default_rng(0)
    grads = rng.normal(size=(cfg.n_servers, 8)).astype(np.float32)
    import jax.numpy as jnp
    spec = encoding.QuantSpec(bits=cfg.bits, block=0)
    scale = jnp.max(jnp.abs(jnp.asarray(grads)))[None]
    u, _ = encoding.quantize(jnp.asarray(grads), spec, scale=scale)
    sym = encoding.pam4_encode(u, cfg.bits)
    print(f"server 0 gradient {grads[0, 0]:+.3f} -> PAM4 symbols "
          f"{np.asarray(sym)[0, 0].tolist()}")

    # --- step 3: hardware-aware training ---
    tc = training.TrainConfig(epochs=epochs, e1=e1, lr=1e-2, proj_every=200)
    params, hist = training.train(cfg, tc, a, t, eval_every=200, verbose=True)
    acc = training.accuracy(params, a, t, cfg)
    print(f"ONN accuracy: {acc:.6f} (paper: 1.0)")

    # --- step 4: MZI programming + optical verification ---
    # numpy oracle on a slice, fast jax emulator on the same slice
    import jax
    from repro.photonics import mesh
    hw = onn.map_to_hardware(params, cfg)
    sw_out = np.asarray(training.apply_onn(params, a[:128], cfg))
    hw_out = onn.apply_hardware(hw, a[:128], cfg)
    print(f"MZI-mesh vs software max |diff|: {np.abs(hw_out - sw_out).max():.2e}")
    progs = mesh.compile_hardware(hw)
    emu_out = np.asarray(jax.jit(
        lambda x: mesh.apply_hardware(progs, x, cfg))(jnp.asarray(a[:128])))
    print(f"jax emulator vs numpy oracle max |diff|: "
          f"{np.abs(emu_out - hw_out).max():.2e}")

    if scenario1:
        # persist for benchmarks/table1.py and the runtime's 'results'
        # source (--fidelity onn/mesh at bits=8)
        import pathlib
        import pickle
        out = pathlib.Path("results")
        out.mkdir(exist_ok=True)
        with open(out / "scenario1_params.pkl", "wb") as f:
            pickle.dump({"cfg": cfg, "params": [
                {"w": np.asarray(l["w"]), "b": np.asarray(l["b"])}
                for l in params]}, f)
        print(f"saved trained params -> {out / 'scenario1_params.pkl'}")

    # --- step 5: area ---
    ratio = area.area_ratio(list(cfg.structure), set(cfg.approx_layers))
    print(f"area ratio with matrix approximation: {ratio:.3f} "
          f"({area.area_mzis(list(cfg.structure), set(cfg.approx_layers))} MZIs)")


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--onn", action="store_true",
                    help="run the paper's core ONN pipeline demo")
    ap.add_argument("--scenario1", action="store_true",
                    help="paper Table-I scenario 1 (implies --onn; slow)")
    ap.add_argument("--epochs", type=int, default=0,
                    help="override the ONN training epoch budget (0 = the "
                         "scenario default; use for fast plumbing checks)")
    ap.add_argument("--arch", default="minitron_4b")
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()
    if args.onn or args.scenario1:
        run_onn(args.scenario1, epochs_override=args.epochs)
    else:
        run_api(args)


if __name__ == "__main__":
    main()
