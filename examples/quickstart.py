"""Quickstart: the OptINC pipeline end-to-end on one small scenario.

  PYTHONPATH=src python examples/quickstart.py [--scenario1]

1. N servers quantize + PAM4-encode their gradients (paper eq. 2).
2. The preprocessing unit P merges symbols and averages across servers.
3. An ONN f_theta is trained (hardware-aware, matrix-approximated, eq. 4-7)
   to emit the PAM4 symbols of the quantized average (eq. 3).
4. The trained ONN is programmed onto MZI meshes (Givens decomposition) and
   the optical forward pass is verified against the software model.
5. Area cost with/without matrix approximation is reported (Table I).

Default: a 2-server B=4 scenario that trains to 100% in ~1 minute on CPU.
--scenario1 runs the paper's first Table-I scenario (B=8, N=4, 13^4
samples; ~30-50 min on this container's single core).
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import area, dataset, encoding, onn, training
from repro.core.onn import ONNConfig


def main():
    if "--scenario1" in sys.argv:
        cfg = ONNConfig(structure=(4, 64, 128, 256, 128, 64, 4),
                        approx_layers=(1, 2, 3, 4, 5, 6),
                        bits=8, n_servers=4, k_inputs=4)
        epochs, e1 = 3000, 2400
    else:
        cfg = ONNConfig(structure=(2, 64, 128, 256, 128, 64, 2),
                        approx_layers=(1, 2, 3, 4, 5, 6),
                        bits=4, n_servers=2, k_inputs=2)
        epochs, e1 = 4000, 3200

    print(f"scenario: B={cfg.bits} N={cfg.n_servers} structure={cfg.structure}")
    print(f"dataset size (paper formula): {dataset.dataset_size(cfg)}")
    a, t = dataset.full_dataset(cfg)

    # --- step 1-2: server-side encoding demo ---
    rng = np.random.default_rng(0)
    grads = rng.normal(size=(cfg.n_servers, 8)).astype(np.float32)
    import jax.numpy as jnp
    spec = encoding.QuantSpec(bits=cfg.bits, block=0)
    scale = jnp.max(jnp.abs(jnp.asarray(grads)))[None]
    u, _ = encoding.quantize(jnp.asarray(grads), spec, scale=scale)
    sym = encoding.pam4_encode(u, cfg.bits)
    print(f"server 0 gradient {grads[0, 0]:+.3f} -> PAM4 symbols "
          f"{np.asarray(sym)[0, 0].tolist()}")

    # --- step 3: hardware-aware training ---
    tc = training.TrainConfig(epochs=epochs, e1=e1, lr=1e-2, proj_every=200)
    params, hist = training.train(cfg, tc, a, t, eval_every=200, verbose=True)
    acc = training.accuracy(params, a, t, cfg)
    print(f"ONN accuracy: {acc:.6f} (paper: 1.0)")

    # --- step 4: MZI programming + optical verification ---
    hw = onn.map_to_hardware(params, cfg)
    sw_out = np.asarray(training.apply_onn(params, a[:128], cfg))
    hw_out = onn.apply_hardware(hw, a[:128], cfg)
    print(f"MZI-mesh vs software max |diff|: {np.abs(hw_out - sw_out).max():.2e}")

    # --- step 5: area ---
    ratio = area.area_ratio(list(cfg.structure), set(cfg.approx_layers))
    print(f"area ratio with matrix approximation: {ratio:.3f} "
          f"({area.area_mzis(list(cfg.structure), set(cfg.approx_layers))} MZIs)")


if __name__ == "__main__":
    main()
