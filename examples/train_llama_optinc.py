"""End-to-end distributed training with the OptINC collective (paper
Fig. 7a): the paper's LLaMA-8L-d384 model on a Wikipedia-1B-shaped
synthetic stream, gradient sync via OptINC vs the ring baseline.

  PYTHONPATH=src python examples/train_llama_optinc.py \
      [--steps 300] [--sync optinc|ring|psum] [--error-layers 3,4,5,6] \
      [--mesh 4x1] [--full-scale]

Defaults are sized for this single-core container (~5 min): seq 128,
batch 8, 40 steps. --full-scale uses the paper's shapes (seq 1024,
batch 32, 300+ steps) — run it on real hardware.

Fault tolerance included: checkpoints to results/ckpt/example every 20
steps (params + optimizer + error-feedback residuals); re-run with the
same args after killing the process and it resumes bit-exactly.  This is
a thin client: the flags below are RunSpec overrides handled by
repro.api (RunSpec.from_args -> TrainSession).
"""
import sys

sys.path.insert(0, "src")

from repro.launch import train


def main():
    args = sys.argv[1:]
    steps = "300" if "--full-scale" in args else "40"
    seq = "1024" if "--full-scale" in args else "128"
    batch = "32" if "--full-scale" in args else "8"
    argv = ["--arch", "paper_llama", "--steps", steps,
            "--seq-len", seq, "--global-batch", batch, "--lr", "1e-3",
            "--ckpt-dir", "results/ckpt/example", "--ckpt-every", "20",
            "--resume"]
    if "--full-scale" not in args:
        argv += ["--smoke-config"] if "--smoke" in args else []
    passthrough = [a for a in args if a not in ("--full-scale", "--smoke")]
    train.main(argv + passthrough)


if __name__ == "__main__":
    main()
