"""Batched serving example — a thin client of ``repro.api.ServeSession``:
prefill a batch of prompts, then greedy-decode with the KV cache through
the shard_map serving path (the same programs the decode_32k / long_500k
dry-run cells lower).

  PYTHONPATH=src python examples/serve_decode.py [--arch minitron_4b] \
      [--prompt-len 24] [--gen-len 16] [--batch 4] [--ckpt-dir DIR]

With --ckpt-dir the session serves the newest checkpointed params of a
trained run instead of a fresh init.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointConfig, RunSpec, ServeSession


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="minitron_4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="",
                    help="serve the newest checkpoint from this run")
    args = ap.parse_args()

    spec = RunSpec(arch=args.arch, smoke=True,
                   ckpt=CheckpointConfig(dir=args.ckpt_dir,
                                         resume=bool(args.ckpt_dir)))
    session = ServeSession(spec)
    cfg = session.cfg

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))

    t0 = time.time()
    enc = (jnp.full((args.batch, cfg.enc_frames, cfg.d_model), 0.1,
                    jnp.float32) if cfg.enc_dec else None)
    logits, _ = session.prefill(prompts, enc_frames=enc)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s "
          f"logits {logits.shape}")

    max_seq = args.prompt_len + args.gen_len + 24  # headroom for the cache
    t0 = time.time()
    gen = session.generate(prompts, args.gen_len, max_seq=max_seq)
    dt = time.time() - t0
    print(f"decoded {args.gen_len} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s on 1 CPU core)")
    print("generated ids[0]:", np.asarray(gen[0]).tolist())


if __name__ == "__main__":
    main()
