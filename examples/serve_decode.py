"""Batched serving example: prefill a batch of prompts, then decode with
the KV cache through the shard_map serving path (the same code the
decode_32k / long_500k dry-run cells lower).

  PYTHONPATH=src python examples/serve_decode.py [--arch minitron_4b]
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps
from repro.launch.mesh import make_mesh
from repro.models import lm


def main():
    arch = "minitron_4b"
    if "--arch" in sys.argv:
        arch = sys.argv[sys.argv.index("--arch") + 1]
    cfg = configs.get_smoke(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = steps.make_ctx(mesh)
    params = lm.init_params(cfg, ctx, jax.random.PRNGKey(0))

    batch, prompt_len, gen_len, max_seq = 4, 24, 16, 64
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)))

    pre, _, _ = steps.make_prefill_step(cfg, mesh)
    dec, _, _ = steps.make_decode_step(cfg, mesh)
    pre_j, dec_j = jax.jit(pre), jax.jit(dec, donate_argnums=(1,))

    with jax.set_mesh(mesh):
        t0 = time.time()
        feed = {"tokens": prompts}
        if cfg.enc_dec:
            feed["enc_frames"] = jnp.full((batch, cfg.enc_frames, cfg.d_model),
                                          0.1, jnp.float32)
        logits, _ = pre_j(params, feed)
        print(f"prefill {batch}x{prompt_len}: {time.time() - t0:.2f}s "
              f"logits {logits.shape}")

        # fresh cache sized for the full generation, replay the prompt
        cache = lm.init_cache(cfg, ctx, batch, max_seq)
        for i in range(prompt_len):
            logits, cache = dec_j(params, cache, prompts[:, i:i + 1],
                                  jnp.int32(i))
        tok = jnp.argmax(logits[:, :cfg.vocab], -1)[:, None]
        out = [tok]
        t0 = time.time()
        for i in range(gen_len - 1):
            logits, cache = dec_j(params, cache, tok,
                                  jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, :cfg.vocab], -1)[:, None]
            out.append(tok)
        dt = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
    print(f"decoded {gen_len} tokens x {batch} seqs in {dt:.2f}s "
          f"({batch * gen_len / dt:.1f} tok/s on 1 CPU core)")
    print("generated ids[0]:", np.asarray(gen[0]).tolist())


if __name__ == "__main__":
    main()
