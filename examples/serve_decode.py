"""Serving example — thin clients of the two inference tiers:

1. ``repro.api.ServeSession``: static-batch greedy generation (compiled
   prefill over the prompt batch, then one decode step per token).
2. ``repro.serving.ServeEngine``: continuous batching over a paged KV
   pool — requests with different prompt lengths and budgets are
   admitted, decoded together, and retired independently.

  PYTHONPATH=src python examples/serve_decode.py [--arch minitron_4b] \
      [--prompt-len 24] [--gen-len 16] [--batch 4] [--ckpt-dir DIR]

With --ckpt-dir the session serves the newest checkpointed params of a
trained run instead of a fresh init (add --reload-every N on a live run
to hot-swap newer checkpoints into the engine while it serves).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointConfig, RunSpec, ServeSession


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="minitron_4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="",
                    help="serve the newest checkpoint from this run")
    args = ap.parse_args()

    spec = RunSpec(arch=args.arch, smoke=True,
                   ckpt=CheckpointConfig(dir=args.ckpt_dir,
                                         resume=bool(args.ckpt_dir)))
    session = ServeSession(spec)
    cfg = session.cfg

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))

    t0 = time.time()
    enc = (jnp.full((args.batch, cfg.enc_frames, cfg.d_model), 0.1,
                    jnp.float32) if cfg.enc_dec else None)
    logits, _ = session.prefill(prompts, enc_frames=enc)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s "
          f"logits {logits.shape}")

    max_seq = args.prompt_len + args.gen_len + 24  # headroom for the cache
    t0 = time.time()
    gen = session.generate(prompts, args.gen_len, max_seq=max_seq,
                           enc_frames=enc)
    dt = time.time() - t0
    print(f"decoded {args.gen_len} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s on 1 CPU core)")
    print("generated ids[0]:", np.asarray(gen[0]).tolist())

    # ---- continuous batching: mixed prompt lengths + budgets through the
    # paged-KV engine (dense-attention archs only)
    from repro.serving import supports_paged
    if not supports_paged(cfg):
        print(f"{cfg.name}: no paged cache — skipping the engine demo")
        return
    engine = session.engine()
    reqs = [rng.integers(0, cfg.vocab,
                         (args.prompt_len - 4 + 3 * (i % 4),)).tolist()
            for i in range(args.batch * 2)]
    budgets = [args.gen_len - 4 + 2 * (i % 5) for i in range(len(reqs))]
    t0 = time.time()
    results = {}
    rids = [engine.submit(p, b) for p, b in zip(reqs, budgets)]
    n_steps = 0
    while engine.has_work():
        engine.step()
        n_steps += 1
    dt = time.time() - t0
    toks = sum(len(engine.results[r]) for r in rids)
    print(f"continuous batching: {len(reqs)} reqs, {toks} tokens in "
          f"{n_steps} steps / {dt:.2f}s ({toks / dt:.1f} tok/s, peak "
          f"concurrency {engine.max_observed_active})")
    print("engine ids[rid 0]:", engine.results[rids[0]])


if __name__ == "__main__":
    main()
