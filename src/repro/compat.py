"""jax version compatibility shims.

The codebase targets the modern jax surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``); the container pins jax
0.4.37 where those live elsewhere or don't exist.  Importing this module
installs fallbacks onto the ``jax`` namespace so every call site — runtime
modules, tests, and the inline subprocess scripts in tests/benchmarks
(which use ``jax.shard_map`` / ``jax.set_mesh`` directly after importing a
repro module) — works on both API generations:

  jax.shard_map   -> jax.experimental.shard_map.shard_map, translating the
                     ``check_vma`` kwarg to 0.4.x's ``check_rep``
  jax.set_mesh    -> the Mesh object itself (Mesh is a context manager on
                     0.4.x, so ``with jax.set_mesh(mesh):`` keeps working)
  make_mesh(...)  -> drops ``axis_types`` when jax.make_mesh predates it

Every module that touches these APIs imports repro.compat first.  The
shims are no-ops on jax versions that already provide the real thing.
"""
from __future__ import annotations

import contextlib
import inspect

import jax

try:
    from jax.sharding import AxisType  # noqa: F401  (modern jax)
    HAS_AXIS_TYPE = True
except ImportError:  # jax <= 0.4.x
    AxisType = None
    HAS_AXIS_TYPE = False

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(shape, axes):
    """jax.make_mesh with AxisType.Auto on every axis when supported."""
    if HAS_AXIS_TYPE and _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map_04x

    def _shard_map(f, mesh=None, in_specs=None, out_specs=None,
                   check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map_04x(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

    jax.shard_map = _shard_map


if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        # psum of a static literal is special-cased to the axis size at
        # trace time — no collective is emitted.
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = _axis_size


if not hasattr(jax, "set_mesh"):
    def _set_mesh(mesh):
        # 0.4.x Mesh is itself a context manager; entering it provides the
        # resource env that modern ``jax.set_mesh`` would.
        if hasattr(mesh, "__enter__"):
            return mesh
        return contextlib.nullcontext(mesh)

    jax.set_mesh = _set_mesh
