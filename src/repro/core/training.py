"""DEPRECATED shim — moved to ``repro.photonics.training``.

The optical subsystem now lives in the ``repro.photonics`` package
(one device-resident home for encoding, the ONN, MZI programming, the
jittable mesh emulator, and the area/error models).  This module
re-exports that surface for pre-refactor importers; new code should
import ``repro.photonics.training`` directly.
"""
from ..photonics.training import *  # noqa: F401,F403
