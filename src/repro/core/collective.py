"""Gradient-synchronization collectives — moved to ``repro.collectives``.

This module is the backwards-compatible import surface for the old
per-leaf implementation that lived here.  The runtime is now the
bucket-fused pluggable engine in ``repro.collectives`` (see that
package's docstring and EXPERIMENTS.md §Fig6); ``sync_gradients`` keeps
its historical signature, with the error-feedback residual now a single
1-D f32 vector over the concatenated leaf space instead of a pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..collectives import (  # noqa: F401
    SyncConfig, available_backends, get_backend, register_backend,
    residual_size, sync_gradients)
from ..collectives.backends import _ring_allreduce_flat


def ring_allreduce(tree, axes):
    """Tree-wise manual ring all-reduce (sum) over ``axes`` — kept for the
    pre-refactor API; the engine runs the fused-bucket equivalent."""
    def leaf(x):
        out = x.reshape(-1)
        for ax in axes:
            out = _ring_allreduce_flat(out, ax)
        return out.reshape(x.shape)
    return jax.tree.map(leaf, tree)
