"""Gradient-synchronization collectives (inside shard_map).

Three modes, selectable per training run (paper Fig. 6/7 comparison):

  psum    — XLA-native all-reduce (reference).
  ring    — faithful ring all-reduce: (N-1) reduce-scatter rounds +
            (N-1) all-gather rounds via lax.ppermute (the paper's baseline,
            with its 2(N-1)/N communication blow-up visible in the HLO).
  optinc  — the paper's technique, TPU-adapted: PAM4-style block
            quantization to B-bit integers *before* crossing the sync axes,
            integer reduction (the ICI analogue of the optical in-network
            sum), then the ONN behavioural transfer function
            Q(mean) applied once (eq. 3), with optional Table-II error
            injection and optional error feedback (beyond-paper).

All functions assume they run inside shard_map and operate on gradient
pytrees whose leaves are identical across the sync axes' peers.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .encoding import QuantSpec, compute_scale
from . import error_model


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mode: str = "optinc"            # psum | ring | optinc
    axes: tuple = ("data",)         # mesh axes to synchronize over
    bits: int = 8                    # OptINC gradient bit width B
    block: int = 2048                # quantization block size (0 = global)
    error_layers: tuple = ()         # Table II key, () = ideal ONN
    error_feedback: bool = False     # beyond-paper residual accumulation


def _axis_size(axes) -> int:
    n = 1
    for ax in axes:
        n *= lax.axis_size(ax)
    return n


# ------------------------------ ring ------------------------------

def _ring_allreduce_leaf(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Manual ring all-reduce of one leaf over one mesh axis: reduce-scatter
    then all-gather, each via (N-1) ppermute rounds (paper Fig. 1)."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    # Rounds are Python-unrolled so every ppermute appears in the HLO
    # (static collective accounting sees all 2(N-1) rounds) and XLA can
    # overlap consecutive rounds.
    # Reduce-scatter: after round r, each device has accumulated chunk
    # (idx - r - 1) mod n from its r+1 upstream neighbours.
    for r in range(n - 1):
        send_id = (idx - r) % n
        recv_id = (idx - r - 1) % n
        sent = lax.ppermute(chunks[send_id], axis, fwd)
        chunks = chunks.at[recv_id].add(sent)

    # All-gather: circulate the fully-reduced chunks.
    for r in range(n - 1):
        send_id = (idx + 1 - r) % n
        recv_id = (idx - r) % n
        sent = lax.ppermute(chunks[send_id], axis, fwd)
        chunks = chunks.at[recv_id].set(sent)
    out = chunks.reshape(-1)
    return out[: x.size].reshape(x.shape)


def ring_allreduce(tree, axes) -> object:
    out = tree
    for ax in axes:
        out = jax.tree.map(lambda x: _ring_allreduce_leaf(x, ax), out)
    return out


# ----------------------------- optinc -----------------------------

def _optinc_leaf(g: jnp.ndarray, cfg: SyncConfig, key: jax.Array | None):
    """Quantize -> integer in-network sum -> Q(mean) -> dequantize."""
    spec = QuantSpec(bits=cfg.bits, block=cfg.block)
    n = _axis_size(cfg.axes)
    g32 = g.astype(jnp.float32)
    # Shared scale across peers ("global block quantization", paper IV —
    # the <0.4% synchronization cost): max over the sync axes.
    scale = compute_scale(g32, spec)
    for ax in cfg.axes:
        scale = lax.pmax(scale, ax)
    # Offset-binary B-bit encode (what each server's transceivers emit).
    blocks_shape = scale.shape[0]
    flat = g32.reshape(-1)
    pad = (-flat.size) % max(cfg.block, 1) if cfg.block > 0 else 0
    flat = jnp.pad(flat, (0, pad)).reshape(blocks_shape, -1)
    q = jnp.round(flat / scale[:, None] * spec.levels)
    q = jnp.clip(q, -spec.levels, spec.levels).astype(jnp.int32)
    u = q + spec.levels
    # In-network computation: the optical sum. The TPU ICI analogue keeps
    # the wire at symbol width: reduce-scatter the B-bit codes in the
    # narrowest integer type that holds the N-way sum, apply the ONN
    # transfer function Q(mean) on the scattered shard, and all-gather the
    # B-bit result. Wire bytes: RS(int16) + AG(int8) = 3 B/elem vs the
    # bf16 ring baseline's 2 x 2 B/elem (see EXPERIMENTS.md §Fig6).
    max_sum = (2 ** cfg.bits - 2) * n
    rs_dt = jnp.int16 if max_sum < 2 ** 15 else jnp.int32
    sizes = [lax.axis_size(ax) for ax in cfg.axes]
    group = 1
    for s_ in sizes:
        group *= s_
    flat_u = u.reshape(-1)
    pad_u = (-flat_u.size) % group
    parts = jnp.pad(flat_u, (0, pad_u)).astype(rs_dt)
    for ax in cfg.axes:
        parts = lax.psum_scatter(parts, ax, scatter_dimension=0, tiled=True)
    u_avg = jnp.round(parts.astype(jnp.float32) / n).astype(jnp.int32)
    if cfg.error_layers and key is not None:
        spec_err = error_model.TABLE_II[tuple(cfg.error_layers)]
        u_avg = error_model.inject(key, u_avg, spec_err, cfg.bits)
    ag_dt = jnp.uint8 if cfg.bits <= 8 else jnp.uint16
    coded = u_avg.astype(ag_dt)
    for ax in reversed(cfg.axes):
        coded = lax.all_gather(coded, ax, axis=0, tiled=True)
    u_avg = coded[: flat_u.size].astype(jnp.int32).reshape(u.shape)
    deq = (u_avg.astype(jnp.float32) - spec.levels) * (scale[:, None] / spec.levels)
    out = deq.reshape(-1)[: g.size].reshape(g.shape)
    # local quantization error (for error feedback): what this server's
    # transceiver lost when encoding its own gradient
    local_deq = (q.astype(jnp.float32)) * (scale[:, None] / spec.levels)
    local_err = g32 - local_deq.reshape(-1)[: g.size].reshape(g.shape)
    return out.astype(g.dtype), local_err


def optinc_allreduce(tree, cfg: SyncConfig, key: jax.Array | None = None):
    leaves, treedef = jax.tree.flatten(tree)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    pairs = [_optinc_leaf(g, cfg, k) for g, k in zip(leaves, keys)]
    out = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return out, err


# --------------------------- entry point ---------------------------

def sync_gradients(grads, cfg: SyncConfig, key: jax.Array | None = None,
                   residual=None):
    """Synchronize (average) ``grads`` across cfg.axes.

    Returns (synced_grads, new_residual). ``residual`` implements error
    feedback (beyond-paper): the local quantization error is added back
    into the next step's gradient before quantization.
    """
    n = _axis_size(cfg.axes)
    if cfg.error_feedback and residual is not None:
        grads = jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, residual)
    new_residual = None
    if cfg.mode == "psum":
        synced = jax.tree.map(
            lambda g: lax.pmean(g, cfg.axes[0] if len(cfg.axes) == 1 else cfg.axes),
            grads)
    elif cfg.mode == "ring":
        synced = jax.tree.map(lambda g: g / n, ring_allreduce(grads, cfg.axes))
    elif cfg.mode == "optinc":
        synced, local_err = optinc_allreduce(grads, cfg, key)
        if cfg.error_feedback:
            new_residual = local_err
    else:
        raise ValueError(f"unknown sync mode {cfg.mode!r}")
    return synced, new_residual
