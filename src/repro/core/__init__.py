# Paper-core remainder.  The optical subsystem (encoding, ONN, MZI
# programming + mesh emulator, training, area/error models) moved to
# repro.photonics; the modules of that name left here are thin
# deprecation re-export shims.  Still first-class here: cascade.py
# (two-level carry-cascade math, eq. 8-10) and collective.py (the
# pre-refactor import surface of repro.collectives).
