# Paper-core remainder.  The optical subsystem (encoding, ONN, MZI
# programming + mesh emulator, training, area/error models — and, since
# the pipeline refactor, cascade.py's two-level carry-cascade math) moved
# to repro.photonics; the modules of that name left here are thin
# deprecation re-export shims.  Still first-class here: collective.py
# (the pre-refactor import surface of repro.collectives).
