"""DEPRECATED shim — moved to ``repro.photonics.area``.

The optical subsystem now lives in the ``repro.photonics`` package
(one device-resident home for encoding, the ONN, MZI programming, the
jittable mesh emulator, and the area/error models).  This module
re-exports that surface for pre-refactor importers; new code should
import ``repro.photonics.area`` directly.
"""
from ..photonics.area import *  # noqa: F401,F403
