"""DEPRECATED shim — moved to ``repro.photonics.cascade``.

The two-level carry-cascade math (paper III-C, eq. 8-10) now lives with
the rest of the optical subsystem in ``repro.photonics``; the photonic
sync pipeline (``repro.photonics.pipeline``) emulates the same eq.-10
carry through the ONN/mesh stages.  This module re-exports that surface
for pre-refactor importers; new code should import
``repro.photonics.cascade`` directly.
"""
from ..photonics.cascade import *  # noqa: F401,F403
from ..photonics.cascade import (CascadeConfig, basic_cascade,  # noqa: F401
                                 carry_cascade, expected, extra_symbols,
                                 hardware_overhead)
