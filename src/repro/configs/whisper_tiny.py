"""Whisper-tiny backbone: enc-dec; conv frontend is a STUB (input_specs
feeds precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=6, d_ff=1536, vocab=51865, enc_dec=True, n_enc_layers=4,
    enc_frames=1500,
)
SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", n_layers=2, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=128, vocab=128, enc_dec=True, n_enc_layers=2,
    enc_frames=32,
)
