"""xLSTM-125M: sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, ssm="xlstm", slstm_every=4,
)
SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm", n_layers=4, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=0, vocab=128, ssm="xlstm", slstm_every=2,
)
