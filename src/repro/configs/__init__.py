"""Architecture registry: --arch <id> resolves here.

Each module defines CONFIG (full assigned config) and SMOKE (reduced
same-family config for CPU smoke tests) plus SHAPES (the assigned
input-shape cells).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "xlstm_125m", "zamba2_7b", "minitron_4b", "llama3_405b",
    "deepseek_coder_33b", "qwen3_32b", "whisper_tiny", "phi35_moe_42b",
    "deepseek_v3_671b", "chameleon_34b",
]

# assigned input shapes (same set for every LM arch)
SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}


def get(arch: str):
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def get_smoke(arch: str):
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.SMOKE


def runs_long_context(cfg) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid) archs."""
    return cfg.ssm in ("mamba2", "xlstm")


def cells(arch: str):
    """The (shape -> spec) cells this arch runs (skips documented)."""
    cfg = get(arch)
    out = {}
    for name, spec in SHAPES.items():
        if name == "long_500k" and not runs_long_context(cfg):
            out[name] = {**spec, "skip": "full-attention arch (quadratic)"}
        else:
            out[name] = dict(spec)
    return out
