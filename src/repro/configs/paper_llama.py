"""The paper's own end-to-end model: LLaMA-based, 8 layers, hidden 384,
8 heads (paper IV, Fig. 7a), trained on Wikipedia-1B-shaped data."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-llama", family="dense", n_layers=8, d_model=384, n_heads=8,
    n_kv_heads=8, d_ff=1536, vocab=32000,
)
SMOKE = CONFIG
