"""Chameleon-34B: early-fusion VLM — VQ image tokens are ordinary vocab
entries, so the backbone is a dense GQA transformer with qk-norm
[arXiv:2405.09818]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True,
)
SMOKE = ModelConfig(
    name="chameleon-smoke", family="vlm", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=128, qk_norm=True,
)
