"""DeepSeek-V3 671B: MLA, 1 shared + 256 routed experts top-8, MTP
[arXiv:2412.19437]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=18432, vocab=129280, moe=True,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, head_dim=128, mtp=True,
)
SMOKE = ModelConfig(
    name="dsv3-smoke", family="moe", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab=128, moe=True, n_experts=8,
    n_shared_experts=1, top_k=2, moe_d_ff=64, first_dense_layers=1,
    mla=True, q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, head_dim=16,
    mtp=True,
)
