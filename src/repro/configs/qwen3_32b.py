"""Qwen3-32B: dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120, n_heads=64,
    n_kv_heads=8, d_ff=25600, vocab=151936, qk_norm=True,
)
SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=128, qk_norm=True,
)
