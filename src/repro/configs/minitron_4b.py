"""Minitron-4B: pruned Nemotron dense GQA [arXiv:2407.14679]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=9216, vocab=256000,
)
SMOKE = ModelConfig(
    name="minitron-smoke", family="dense", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=128,
)
