"""Zamba2-7B: Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab=32000, ssm="mamba2", ssm_state=64,
    attn_every=6,
)
SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=7, d_model=64, n_heads=2,
    n_kv_heads=2, d_ff=256, vocab=128, ssm="mamba2", ssm_state=16,
    attn_every=3,
)
