"""AdamW with dtype-configurable moments (bf16 moments halve the optimizer
memory roofline; see EXPERIMENTS.md §Perf) and global-norm clipping.

Pure local functions: they run inside shard_map on local parameter shards;
gradient synchronization happens *before* the update (core.collective), so
the update is identical on every replica. The global-norm clip reduces over
the model axes so the clip factor is consistent across shards.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" halves optimizer memory


def _mdt(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def adamw_init(cfg: AdamWConfig, params):
    dt = _mdt(cfg)
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float, axis_names=()):
    """Global-norm clip; the squared norm is psum'd over ``axis_names`` so
    sharded parameters contribute their full norm."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    for ax in axis_names:
        sq = lax.psum(sq, ax)
    norm = jnp.sqrt(sq)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
                        grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    t = state["step"] + 1
    dt = _mdt(cfg)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / (1 - b1 ** t.astype(jnp.float32))
        vhat = v32 / (1 - b2 ** t.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(treedef, [n[0] for n in new])
    m = jax.tree.unflatten(treedef, [n[1] for n in new])
    v = jax.tree.unflatten(treedef, [n[2] for n in new])
    return params, {"m": m, "v": v, "step": t}
