"""Deterministic synthetic data pipeline.

Offline container ⇒ no real corpora; streams are deterministic functions of
(seed, step, shard) so that:
  * a restarted/replaced worker reproduces its shard exactly (straggler /
    failure recovery needs no shared iterator state), and
  * loss curves are comparable across sync modes (ring vs optinc) because
    both see identical tokens.

The LM stream is a Zipfian Markov-ish token process shaped like the paper's
Wikipedia-1B setup (vocab 32000); a structured component makes the loss
meaningfully learnable (next token depends on the previous one).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32000
    seq_len: int = 512
    global_batch: int = 32
    seed: int = 1234
    zipf_a: float = 1.2


class SyntheticLM:
    """Deterministic-by-(step, shard) synthetic LM token stream."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        assert cfg.global_batch % num_shards == 0
        self.local_batch = cfg.global_batch // num_shards
        # fixed Zipfian unigram table + deterministic bigram shift
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self.probs = (ranks ** -cfg.zipf_a)
        self.probs /= self.probs.sum()
        self.shift = rng.integers(1, cfg.vocab, size=cfg.vocab)

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq_len + 1) int32 tokens for this shard/step."""
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.shard)
        t = self.cfg.seq_len + 1
        base = rng.choice(self.cfg.vocab, size=(self.local_batch, t),
                          p=self.probs)
        # 50% of positions follow the deterministic bigram map (learnable)
        follow = rng.random((self.local_batch, t)) < 0.5
        out = base.copy()
        for i in range(1, t):
            out[:, i] = np.where(follow[:, i],
                                 self.shift[out[:, i - 1]], base[:, i])
        return out.astype(np.int32)


def make_batch_iterator(cfg: DataConfig, start_step: int = 0, shard: int = 0,
                        num_shards: int = 1):
    ds = SyntheticLM(cfg, shard, num_shards)
    step = start_step
    while True:
        yield step, {"tokens": ds.batch(step)}
        step += 1


def synthetic_images(step: int, batch: int, seed: int = 7,
                     shape=(32, 32, 3), classes: int = 100):
    """CIFAR-100-shaped deterministic image stream (paper's ResNet50 task):
    class-conditional Gaussian blobs (learnable but non-trivial)."""
    rng = np.random.default_rng(seed * 999_983 + step)
    labels = rng.integers(0, classes, size=batch)
    protos = np.random.default_rng(seed).normal(size=(classes, 8)).astype(np.float32)
    noise = rng.normal(size=(batch,) + shape).astype(np.float32)
    grid = np.linspace(0, 1, shape[0] * shape[1] * shape[2]).reshape(shape)
    imgs = noise * 0.5
    for i in range(batch):
        f = protos[labels[i]]
        imgs[i] += (f[:4].reshape(2, 2, 1) * grid[:2, :2] * 0).sum() + \
            f.mean() + 0.3 * np.outer(np.sin(np.linspace(0, f[0] * 6, shape[0])),
                                      np.cos(np.linspace(0, f[1] * 6, shape[1])))[..., None]
    return imgs.astype(np.float32), labels.astype(np.int32)
