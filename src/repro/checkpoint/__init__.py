from .ckpt import (CheckpointManager, latest_step, load_checkpoint,
                   read_manifest, save_checkpoint)
