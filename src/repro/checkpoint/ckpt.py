"""Sharded, atomic, resumable checkpoints with elastic re-shard on restore.

Layout:  <dir>/step_<N>/
            manifest.json       step, mesh shape, tree structure, per-leaf
                                global shape/dtype/PartitionSpec, content hash
                                (+ the run's RunSpec under extra.run_spec)
            arrays.npz          one entry per flattened leaf (global arrays;
                                per-host shard files in a true multi-host
                                deployment — single-host here). Subtrees:
                                params/, opt/, and — when error feedback is
                                on — sync/ (the residual vectors), so a
                                resumed run restores residuals bit-exactly.

Guarantees:
  * atomic: written to step_<N>.tmp then os.replace()'d — a crash mid-write
    never yields a manifest that validates.
  * resumable: ``latest_step`` skips unreadable/partial checkpoints.
  * elastic: restore() re-shards to ANY mesh by placing the global arrays
    with the target mesh's NamedSharding (mesh shape may differ from the
    one used at save time).
  * async: save(..., background=True) runs in a writer thread; the train
    loop only blocks if a previous save is still in flight.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


def save_checkpoint(direc, step: int, params, opt_state=None, sync_state=None,
                    extra=None, background: bool = False):
    direc = pathlib.Path(direc)
    direc.mkdir(parents=True, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt"] = opt_state
    if sync_state:  # error-feedback residuals ({} / None = nothing to save)
        tree["sync"] = sync_state
    paths, leaves, _ = _flatten_with_paths(tree)
    # pull to host before handing to the writer thread; store extended
    # dtypes (bfloat16) as float32 — npz cannot round-trip them
    def to_host(x):
        a = np.asarray(x)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = np.asarray(jax.numpy.asarray(x).astype(jax.numpy.float32))
        return a
    host_leaves = [to_host(x) for x in leaves]

    def write():
        tmp = direc / f"step_{step}.tmp"
        final = direc / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        arrays = dict(zip(paths, host_leaves))
        np.savez(tmp / "arrays.npz", **arrays)
        h = hashlib.sha256()
        for p in paths:
            h.update(p.encode())
            h.update(arrays[p].tobytes()[:4096])
        manifest = {
            "step": step,
            "leaves": {p: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for p, a in arrays.items()},
            "extra": extra or {},
            "hash": h.hexdigest(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def read_manifest(direc, step: int) -> dict:
    """The manifest dict of one checkpoint (step, leaves, extra, hash) —
    cheap spec/structure inspection without loading the arrays."""
    p = pathlib.Path(direc) / f"step_{step}" / "manifest.json"
    return json.loads(p.read_text())


def read_subtree_arrays(direc, step: int, prefix: str) -> dict:
    """Raw arrays of ONE checkpoint subtree as a nested dict (no template
    needed — the structure comes from the stored leaf paths).

    For subtrees whose shape the caller cannot know up front, e.g. the
    block-sparse error-feedback residuals (``sync/<name>/{idx,val,shape}``,
    variable nonzero-block count) restored by ``api.session``.  Keeping
    this here means the session layer never touches the on-disk layout.
    """
    direc = pathlib.Path(direc) / f"step_{step}"
    data = np.load(direc / "arrays.npz")
    out = {}
    for p in data.files:
        parts = p.split("/")
        if parts[0] != prefix:
            continue
        node = out
        for seg in parts[1:-1]:
            node = node.setdefault(seg, {})
        node[parts[-1]] = data[p]
    return out


def latest_step(direc) -> int | None:
    direc = pathlib.Path(direc)
    if not direc.exists():
        return None
    steps = []
    for p in direc.glob("step_*"):
        if p.name.endswith(".tmp"):
            continue
        try:
            man = json.loads((p / "manifest.json").read_text())
            steps.append(int(man["step"]))
        except Exception:
            continue  # partial/corrupt checkpoint: skip
    return max(steps) if steps else None


def load_checkpoint(direc, step: int, template, mesh=None, specs=None):
    """Restore into ``template``'s tree structure. With (mesh, specs) the
    arrays are placed sharded — use a DIFFERENT mesh than at save time to
    re-shard elastically."""
    direc = pathlib.Path(direc) / f"step_{step}"
    man = json.loads((direc / "manifest.json").read_text())
    data = np.load(direc / "arrays.npz")
    paths, leaves, treedef = _flatten_with_paths(template)
    out = []
    spec_leaves = None
    if specs is not None:
        _, spec_leaves, _ = _flatten_with_paths(specs)
    for i, (p, ref) in enumerate(zip(paths, leaves)):
        if p not in data.files:
            raise ValueError(
                f"checkpoint {direc} has no leaf {p!r} (saved leaves: "
                f"{sorted(man['leaves'])[:8]}...) — the template's tree "
                f"structure does not match the saved run")
        arr = data[p]
        want = man["leaves"][p]
        assert list(arr.shape) == want["shape"], (p, arr.shape, want)
        ref_shape = tuple(np.shape(ref))
        if tuple(arr.shape) != ref_shape:
            # the reshard path re-PLACES global arrays; it never reshapes
            # them.  A template whose global shape disagrees with the
            # saved leaf is a different run (arch/width/bucket change),
            # not a reshard — fail loudly instead of letting device_put
            # scatter garbage.
            raise ValueError(
                f"checkpoint leaf {p!r}: saved global shape "
                f"{tuple(arr.shape)} != template shape {ref_shape} — the "
                f"checkpoint was written by a run with a different state "
                f"structure and cannot be restored into this one")
        if mesh is not None and spec_leaves is not None:
            sh = jax.sharding.NamedSharding(mesh, spec_leaves[i])
            out.append(jax.device_put(
                jax.numpy.asarray(arr).astype(ref.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr).astype(ref.dtype))
    return jax.tree.unflatten(treedef, out), man


class CheckpointManager:
    """Keeps the last K checkpoints, one async save in flight."""

    def __init__(self, direc, keep: int = 3):
        self.direc = pathlib.Path(direc)
        self.keep = keep
        self._inflight = None

    def save(self, step, params, opt_state=None, sync_state=None, extra=None):
        if self._inflight is not None:
            self._inflight.join()
        self._inflight = save_checkpoint(self.direc, step, params, opt_state,
                                         sync_state, extra, background=True)
        self._gc()

    def wait(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.direc.glob("step_*")
            if not p.name.endswith(".tmp") and (p / "manifest.json").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.direc / f"step_{s}", ignore_errors=True)
