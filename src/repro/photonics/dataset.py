"""ONN training datasets (paper III-A and III-C).

With the preprocessing unit P, each ONN input A_k takes values
{0, 1/N, 2/N, ..., 4^g - 1} — i.e. V = N*(4^g - 1) + 1 distinct values —
so the full dataset has V^K samples (vs 2^(M*N) without P).

Targets are the PAM4 symbols of Q(sum_k A_k * 4^(g*(K-k))) (exact
behavioural transfer function, eq. 3).

For the cascading topology (III-C), level-1 OptINCs keep the discarded
decimal part d as an extra, higher-resolution output symbol (eq. 10), and
both levels train on correspondingly modified datasets.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import (num_symbols, pam4_encode, preprocess_group_size)
from .onn import ONNConfig


def grid_values(cfg: ONNConfig) -> np.ndarray:
    """All V distinct values one preprocessed input A_k can take."""
    g = preprocess_group_size(cfg.bits, cfg.k_inputs)
    v = cfg.n_servers * (4 ** g - 1) + 1
    return np.arange(v, dtype=np.float64) / cfg.n_servers


def dataset_size(cfg: ONNConfig) -> int:
    return len(grid_values(cfg)) ** cfg.k_inputs


def _targets_from_inputs(a: np.ndarray, cfg: ONNConfig) -> np.ndarray:
    g = preprocess_group_size(cfg.bits, cfg.k_inputs)
    k = cfg.k_inputs
    w = (4.0 ** g) ** np.arange(k - 1, -1, -1)
    total = np.round(a @ w).astype(np.int64)
    m = num_symbols(cfg.bits)
    shifts = 4 ** np.arange(m - 1, -1, -1, dtype=np.int64)
    return ((total[:, None] // shifts) % 4).astype(np.int32)


def full_dataset(cfg: ONNConfig):
    """Enumerate the complete (V^K, K) input grid + PAM4 targets."""
    vals = grid_values(cfg)
    k = cfg.k_inputs
    grids = np.meshgrid(*([vals] * k), indexing="ij")
    a = np.stack([g.reshape(-1) for g in grids], axis=-1)
    return a.astype(np.float32), _targets_from_inputs(a, cfg)


def sampled_dataset(cfg: ONNConfig, rng: np.random.Generator, count: int):
    """Uniform sample of the grid — used for the scenarios whose full grid
    (up to 13.8M samples) exceeds this container's budget."""
    vals = grid_values(cfg)
    idx = rng.integers(0, len(vals), size=(count, cfg.k_inputs))
    a = vals[idx]
    return a.astype(np.float32), _targets_from_inputs(a, cfg)


def server_side_dataset(cfg: ONNConfig, rng: np.random.Generator, count: int):
    """End-to-end check data: random B-bit server gradients -> PAM4 encode ->
    P unit -> (A, target symbols of Q(mean))."""
    from . import encoding as enc
    u = rng.integers(0, 2 ** cfg.bits - 1, size=(cfg.n_servers, count),
                     dtype=np.int64)
    sym = np.asarray(enc.pam4_encode(jnp.asarray(u), cfg.bits))
    a = np.asarray(enc.preprocess(jnp.asarray(sym), cfg.bits, cfg.k_inputs))
    tgt = np.asarray(enc.expected_avg_symbols(jnp.asarray(sym), cfg.bits))
    return a.astype(np.float32), tgt.astype(np.int32)
