"""The ONN f_theta: an MLP with ReLU activations (paper IV) whose linear
layers are MZI-implementable. Dense weights are used during training; the
matrix-approximation projection (approx.approx_matrix) is applied
periodically and enforced at mapping time (paper III-B).

Inputs are the preprocessed signals A_k scaled to [0, 1]; outputs are M
analog values that the transceivers quantize to the nearest PAM4 level.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import approx as approx_mod
from . import area as area_mod
from . import mzi as mzi_mod
# Module-level import: in the photonics package layout ``encoding`` depends
# on nothing else in the package, so the historical function-local import
# (which papered over a core/ import cycle) is gone for good
# (tests/test_photonics.py::test_no_import_cycle).
from .encoding import preprocess_group_size


@dataclasses.dataclass(frozen=True)
class ONNConfig:
    structure: tuple  # e.g. (4, 64, 128, 256, 128, 64, 4)
    approx_layers: tuple = ()  # 1-based layer indices to approximate
    bits: int = 8              # B: gradient bit width
    n_servers: int = 4         # N
    k_inputs: int = 4          # K (ONN input size after the P unit)

    @property
    def in_scale(self) -> float:
        """A_k ranges over [0, 4^g - 1]; normalize to [0, 1]."""
        g = preprocess_group_size(self.bits, self.k_inputs)
        return float(4 ** g - 1)

    @property
    def out_scale(self) -> float:
        return 3.0  # PAM4 symbol levels {0,1,2,3}


def init_params(cfg: ONNConfig, rng: jax.Array):
    params = []
    dims = area_mod.layer_dims(list(cfg.structure))
    keys = jax.random.split(rng, len(dims))
    for key, (m, n) in zip(keys, dims):
        w = jax.random.normal(key, (m, n), jnp.float32) * jnp.sqrt(2.0 / n)
        b = jnp.zeros((m,), jnp.float32)
        params.append({"w": w, "b": b})
    return params


def apply(params, a: jnp.ndarray, cfg: ONNConfig) -> jnp.ndarray:
    """Forward pass. a: (..., K) raw preprocessed inputs -> (..., M) analog
    outputs in symbol units (approximately {0..3})."""
    x = a.astype(jnp.float32) / cfg.in_scale
    n_layers = len(params)
    for i, layer in enumerate(params):
        x = x @ layer["w"].T + layer["b"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x * cfg.out_scale


def project_approx(params, cfg: ONNConfig):
    """Apply the matrix approximation to the selected layers (projection
    step of the hardware-aware training, paper III-B)."""
    out = []
    for idx, layer in enumerate(params, start=1):
        if idx in cfg.approx_layers:
            out.append({"w": approx_mod.approx_matrix(layer["w"]), "b": layer["b"]})
        else:
            out.append(layer)
    return out


@dataclasses.dataclass(frozen=True)
class Transceiver:
    """Receiver-side transceiver: quantize the ONN's analog outputs to the
    nearest PAM4 symbol level (the paper's ADC/decision stage)."""
    levels: int = 3  # PAM4: symbols {0, 1, 2, 3}

    def readout(self, outputs: jnp.ndarray) -> jnp.ndarray:
        return (jnp.clip(jnp.round(outputs), 0, self.levels)
                .astype(jnp.int32))


def readout(outputs: jnp.ndarray) -> jnp.ndarray:
    """Transceiver model: quantize analog outputs to the nearest PAM4 level."""
    return Transceiver().readout(outputs)


def area_ratio(cfg: ONNConfig) -> float:
    return area_mod.area_ratio(list(cfg.structure), set(cfg.approx_layers))


# ---------------- hardware mapping (MZI programming) ----------------

def map_to_hardware(params, cfg: ONNConfig):
    """Program every layer onto MZI meshes. Approximated layers use the
    Sigma_a U_a form (one mesh + diag); others use full SVD (two meshes).
    Returns a list of per-layer hardware programs."""
    hw = []
    for idx, layer in enumerate(params, start=1):
        w = np.asarray(layer["w"], np.float64)
        m, n = w.shape
        if idx in cfg.approx_layers:
            s = approx_mod.block_size(m, n)
            blocks = []
            if m >= n:
                parts = w.reshape(m // s, s, n)
            else:
                parts = w.reshape(m, n // s, s).transpose(1, 0, 2)
            for ws in parts:
                d, ua = approx_mod.approx_block_factors(ws)
                blocks.append({"d": d, "u": mzi_mod.givens_decompose(ua)})
            hw.append({"kind": "approx", "blocks": blocks, "shape": (m, n),
                       "b": np.asarray(layer["b"])})
        else:
            pu, s, pv = mzi_mod.program_matrix_svd(w)
            hw.append({"kind": "svd", "u": pu, "sigma": s, "v": pv,
                       "shape": (m, n), "b": np.asarray(layer["b"])})
    return hw


def apply_hardware(hw, a: np.ndarray, cfg: ONNConfig) -> np.ndarray:
    """Numpy forward pass through the programmed MZI meshes — validates that
    the mapping preserves the trained function."""
    x = np.asarray(a, np.float64) / cfg.in_scale
    for li, layer in enumerate(hw):
        m, n = layer["shape"]
        if layer["kind"] == "svd":
            y = mzi_mod.apply_programmed_svd(layer["u"], layer["sigma"],
                                             layer["v"], x.T).T
        else:
            s = min(m, n)
            if m >= n:
                parts = [b for b in layer["blocks"]]
                ys = [ (mzi_mod.reconstruct(p["u"]) @ x.T).T * p["d"] for p in parts ]
                y = np.concatenate(ys, axis=-1)
            else:
                xs = x.reshape(x.shape[:-1] + (n // s, s))
                y = 0.0
                for j, p in enumerate(layer["blocks"]):
                    y = y + (mzi_mod.reconstruct(p["u"]) @ xs[..., j, :].T).T * p["d"]
        y = y + layer["b"]
        if li < len(hw) - 1:
            y = np.maximum(y, 0.0)
        x = y
    return x * cfg.out_scale
