"""Hardware-aware ONN training (paper III-B, eq. 7).

Two-stage loss:
  stage 1 (E < E1):  per-symbol weighted MSE on the raw analog outputs.
                     W_T^(i) weights MSB symbols more; the paper leaves the
                     exact values unspecified — ``weight_mode`` selects
                     uniform / 2^(M-i) / 4^(M-i) (uniform converges best in
                     our reproduction; see EXPERIMENTS.md §Table1).
  stage 2 (E >= E1): MSE on the reconstructed gradient G_bar from
                     transceiver-quantized outputs (straight-through
                     estimator keeps rounding trainable).

Hardware constraint (matrix approximation) is enforced two ways:
  mode='project' — the paper's algorithm: periodically project the selected
                   layers onto the Sigma_a U_a manifold, enforce at the end.
  mode='cayley'  — beyond-paper: parametrize the selected layers *exactly*
                   as diag(d) @ cayley(P - P^T) per block, so the trained
                   network is hardware-exact by construction (no projection
                   error to recover from).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import approx as approx_mod
from . import onn as onn_mod
from .onn import ONNConfig


@dataclasses.dataclass
class TrainConfig:
    epochs: int = 4000
    e1: int = 3000               # stage-1 epoch count
    lr: float = 1e-2
    batch_size: int = 0          # 0 = full batch
    proj_every: int = 100        # approximation projection period (project mode)
    mode: str = "project"        # project | cayley
    weight_mode: str = "uniform"  # uniform | pow2 | pow4
    seed: int = 0
    cosine: bool = True


def symbol_weights(m: int, mode: str) -> jnp.ndarray:
    if mode == "uniform":
        w = jnp.ones((m,))
    elif mode == "pow2":
        w = 2.0 ** jnp.arange(m - 1, -1, -1)
    elif mode == "pow4":
        w = 4.0 ** jnp.arange(m - 1, -1, -1)
    else:
        raise ValueError(mode)
    return w / jnp.sum(w)


def _ste_round(x: jnp.ndarray) -> jnp.ndarray:
    return x + jax.lax.stop_gradient(jnp.clip(jnp.round(x), 0, 3) - x)


# ----------------- Cayley-constrained parametrization -----------------

def _cayley(p: jnp.ndarray) -> jnp.ndarray:
    """Skew-symmetrize the free matrix and map to the orthogonal group."""
    a = p - jnp.swapaxes(p, -1, -2)
    eye = jnp.eye(a.shape[-1], dtype=a.dtype)
    return jnp.linalg.solve(eye + a, eye - a)


def init_constrained_layer(key, m: int, n: int):
    s = approx_mod.block_size(m, n)
    nblocks = (m // s) * (n // s)
    k1, k2 = jax.random.split(key)
    p = jax.random.normal(k1, (nblocks, s, s), jnp.float32) * 0.1
    d = jax.random.normal(k2, (nblocks, s), jnp.float32) * jnp.sqrt(2.0 / n)
    return {"p": p, "d": d, "b": jnp.zeros((m,), jnp.float32),
            "shape": (m, n)}


def materialize_constrained(layer) -> jnp.ndarray:
    """Build W (m x n) from the exact diag(d) @ U block parametrization."""
    m, n = layer["shape"]
    s = approx_mod.block_size(m, n)
    u = _cayley(layer["p"])                      # (nblocks, s, s)
    w_blocks = layer["d"][..., None] * u         # diag(d) @ U
    if m == n:
        return w_blocks[0]
    if m > n:
        return w_blocks.reshape(m, n)
    return w_blocks.transpose(1, 0, 2).reshape(m, n)


def init_params(cfg: ONNConfig, rng, mode: str):
    """Dense params, with approximated layers replaced by the constrained
    parametrization when mode == 'cayley'."""
    dense = onn_mod.init_params(cfg, rng)
    if mode != "cayley":
        return dense
    keys = jax.random.split(rng, len(dense))
    out = []
    for idx, (layer, key) in enumerate(zip(dense, keys), start=1):
        if idx in cfg.approx_layers:
            m, n = layer["w"].shape
            out.append(init_constrained_layer(key, m, n))
        else:
            out.append(layer)
    return out


def apply_onn(params, a, cfg: ONNConfig):
    """Forward pass that understands both layer parametrizations."""
    x = a.astype(jnp.float32) / cfg.in_scale
    nl = len(params)
    for i, layer in enumerate(params):
        w = layer["w"] if "w" in layer else materialize_constrained(layer)
        x = x @ w.T + layer["b"]
        if i < nl - 1:
            x = jax.nn.relu(x)
    return x * cfg.out_scale


def to_dense(params):
    """Materialize any constrained layers into plain dense weights."""
    out = []
    for layer in params:
        if "w" in layer:
            out.append(layer)
        else:
            out.append({"w": materialize_constrained(layer), "b": layer["b"]})
    return out


# ------------------------------ losses ------------------------------

def stage1_loss(params, a, tgt, cfg: ONNConfig, w_sym):
    out = apply_onn(params, a, cfg)
    return jnp.mean(jnp.sum(w_sym * (out - tgt.astype(jnp.float32)) ** 2, -1))


def stage2_loss(params, a, tgt, cfg: ONNConfig, w_sym):
    out = apply_onn(params, a, cfg)
    m = out.shape[-1]
    place = 4.0 ** jnp.arange(m - 1, -1, -1)
    g_hat = jnp.sum(_ste_round(out) * place, -1)
    g_star = jnp.sum(tgt.astype(jnp.float32) * place, -1)
    scale = 4.0 ** m - 1.0
    # keep a small symbol-level anchor so stage 2 cannot drift symbols that
    # currently round correctly (zero STE gradient regions)
    anchor = jnp.mean(jnp.sum(w_sym * (out - tgt.astype(jnp.float32)) ** 2, -1))
    return jnp.mean(((g_hat - g_star) / scale) ** 2) + 0.1 * anchor


# ----------------------------- metrics ------------------------------

def accuracy(params, a, tgt, cfg: ONNConfig, batch: int = 262144) -> float:
    """Fraction of samples whose entire reconstructed gradient is exact
    (all M symbols round correctly) — the paper's 'ONN Accuracy'."""
    params = to_dense(params)
    n = a.shape[0]
    correct = 0
    fwd = jax.jit(partial(apply_onn, cfg=cfg))
    for i in range(0, n, batch):
        sym = onn_mod.readout(fwd(params, jnp.asarray(a[i:i + batch])))
        correct += int(jnp.sum(jnp.all(sym == jnp.asarray(tgt[i:i + batch]), -1)))
    return correct / n


def error_histogram(params, a, tgt, cfg: ONNConfig, batch: int = 262144):
    """Integer-error distribution of the reconstructed gradient on the
    misclassified samples (paper Table II col 3)."""
    params = to_dense(params)
    m = tgt.shape[-1]
    place = 4 ** np.arange(m - 1, -1, -1)
    errs = {}
    fwd = jax.jit(partial(apply_onn, cfg=cfg))
    for i in range(0, a.shape[0], batch):
        sym = np.asarray(onn_mod.readout(fwd(params, jnp.asarray(a[i:i + batch]))))
        g_hat = (sym * place).sum(-1)
        g_star = (np.asarray(tgt[i:i + batch]) * place).sum(-1)
        for e in (g_hat - g_star)[g_hat != g_star]:
            errs[int(e)] = errs.get(int(e), 0) + 1
    return errs


# ----------------------------- optimizer ----------------------------

def _adam_init(params):
    return {"m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def _adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                          params, mhat, vhat)
    return params, {"m": m, "v": v, "t": t}


# ------------------------------ driver ------------------------------

def train(cfg: ONNConfig, tcfg: TrainConfig, a: np.ndarray, tgt: np.ndarray,
          eval_every: int = 0, verbose: bool = False, target_acc: float = 1.0):
    """Hardware-aware training loop. Returns (params, history). The returned
    params always satisfy the hardware constraint on cfg.approx_layers."""
    rng = jax.random.PRNGKey(tcfg.seed)
    params = init_params(cfg, rng, tcfg.mode)
    m_out = cfg.structure[-1]
    w_sym = symbol_weights(m_out, tcfg.weight_mode)

    # static "shape" fields must not be traced through jit
    def split_static(p):
        dyn = [ {k: v for k, v in l.items() if k != "shape"} for l in p ]
        return dyn

    shapes = [l.get("shape") for l in params]

    def with_shapes(dyn):
        return [dict(l, shape=s) if s is not None else l
                for l, s in zip(dyn, shapes)]

    @partial(jax.jit, static_argnames=("stage",))
    def step(dyn, opt, ab, tb, lr, stage):
        def loss_fn(dyn):
            p = with_shapes(dyn)
            f = stage1_loss if stage == 1 else stage2_loss
            return f(p, ab, tb, cfg, w_sym)
        loss, grads = jax.value_and_grad(loss_fn)(dyn)
        dyn, opt = _adam_update(dyn, grads, opt, lr)
        return dyn, opt, loss

    n = a.shape[0]
    bs = tcfg.batch_size if tcfg.batch_size > 0 else n
    steps = max(1, n // bs)
    history = []
    perm_rng = np.random.default_rng(tcfg.seed)
    a_j, t_j = jnp.asarray(a), jnp.asarray(tgt)
    dyn = split_static(params)
    opt = _adam_init(dyn)
    for epoch in range(tcfg.epochs):
        stage = 1 if epoch < tcfg.e1 else 2
        lr = tcfg.lr
        if tcfg.cosine:
            lr = tcfg.lr * 0.5 * (1 + np.cos(np.pi * epoch / tcfg.epochs))
        if steps == 1:
            dyn, opt, loss = step(dyn, opt, a_j, t_j, lr, stage)
            ep_loss = float(loss)
        else:
            perm = perm_rng.permutation(n)
            ep_loss = 0.0
            for s in range(steps):
                idx = jnp.asarray(perm[s * bs:(s + 1) * bs])
                dyn, opt, loss = step(dyn, opt, a_j[idx], t_j[idx], lr, stage)
                ep_loss += float(loss) / steps
        projected = False
        if (tcfg.mode == "project" and cfg.approx_layers
                and (epoch + 1) % tcfg.proj_every == 0):
            p_full = with_shapes(dyn)
            p_full = onn_mod.project_approx(p_full, cfg)
            dyn = split_static(p_full)
            projected = True
        rec = {"epoch": epoch, "stage": stage, "loss": ep_loss,
               "projected": projected, "lr": lr}
        if eval_every and (epoch + 1) % eval_every == 0:
            p_eval = with_shapes(dyn)
            if tcfg.mode == "project" and cfg.approx_layers:
                p_eval = onn_mod.project_approx(p_eval, cfg)
            rec["acc"] = accuracy(p_eval, a, tgt, cfg)
            if verbose:
                print(f"epoch {epoch:5d} stage {stage} loss {ep_loss:.3e} "
                      f"acc {rec['acc']:.6f}", flush=True)
            if rec["acc"] >= target_acc:
                history.append(rec)
                dyn = split_static(p_eval)
                break
        history.append(rec)
    params = with_shapes(dyn)
    if tcfg.mode == "project" and cfg.approx_layers:
        params = onn_mod.project_approx(params, cfg)
    params = to_dense(params)
    return params, history
