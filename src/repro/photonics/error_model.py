"""ONN error-injection model (paper Table II + Fig. 7a methodology).

When the approximated ONN is not exactly 100% accurate, it perturbs the
integer averaged gradient with specific error values at specific relative
frequencies. The paper injects those errors during end-to-end training to
show the impact is negligible. The table below reproduces paper Table II.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ErrorSpec:
    """P(any error) = 1 - accuracy; conditional on an error, ``values`` are
    drawn with probabilities ``ratios``."""
    accuracy: float
    values: tuple
    ratios: tuple

    @property
    def p_error(self) -> float:
        return 1.0 - self.accuracy


# Paper Table II (scenario 4: B=16, N=4). Keys = approximated layer sets.
TABLE_II = {
    (4, 5, 6): ErrorSpec(1.0, (), ()),
    (4, 5, 6, 7): ErrorSpec(0.9999986, (1, -1, -64), (0.45, 0.45, 0.10)),
    (4, 5, 6, 7, 8): ErrorSpec(0.9999999, (1024,), (1.0,)),
    (3, 4, 5, 6): ErrorSpec(0.9998891,
                            (1, -1, 1024, -1024, -4),
                            (0.495, 0.495, 0.0045, 0.0045, 0.001)),
    (3, 4, 5, 6, 7): ErrorSpec(0.9999936,
                               (4, -4, -16, 12),
                               (0.3975, 0.3975, 0.17, 0.035)),
}


def inject(key: jax.Array, u_avg: jnp.ndarray, spec: ErrorSpec,
           bits: int) -> jnp.ndarray:
    """Inject Table-II integer errors into the averaged gradient ``u_avg``
    (offset-binary ints). Vectorized over the whole tensor."""
    if not spec.values:
        return u_avg
    k1, k2 = jax.random.split(key)
    hit = jax.random.bernoulli(k1, spec.p_error, u_avg.shape)
    vals = jnp.asarray(spec.values, jnp.int32)
    probs = jnp.asarray(spec.ratios, jnp.float32)
    which = jax.random.categorical(k2, jnp.log(probs), shape=u_avg.shape)
    err = vals[which]
    out = u_avg + jnp.where(hit, err, 0)
    return jnp.clip(out, 0, 2 ** bits - 2)
