"""Vectorized, jittable MZI mesh emulator.

``mzi.py`` is the numpy oracle: it rebuilds an orthogonal matrix by
multiplying one m x m Givens matrix per MZI in a Python loop —
O(K m^2) with K = m(m-1)/2 rotations, unjittable and CPU-bound.  This
module is the device-resident counterpart: a phase program is compiled
ONCE into stacked Clements-style rotation layers and applied with one
``lax.scan`` over the layer axis.

Each layer packs its (disjoint) rotations into three full-width wire
vectors — partner permutation ``perm``, diagonal coefficient ``ca`` and
off-diagonal coefficient ``sa`` (untouched wires: identity) — so one
layer application is

    y' = ca * y + sa * y[..., perm]

a single gather + fused elementwise math: no scatters, batched,
jittable, vmap-able, and orders of magnitude faster than the numpy loop
(benchmarks/mesh_emulation.py).  The numpy path is kept only as the
cross-check oracle in tests.

Layering: rotations are greedily scheduled in application order; a
rotation lands in layer ``max(last_layer[wire_i], last_layer[wire_j])+1``,
which preserves ordering between rotations sharing a waveguide and packs
commuting (disjoint) rotations into the same layer — for Clements-style
adjacent-plane programs this approaches the optimal ~2m-3 layer depth.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .config import MESH_BACKENDS
from .mzi import MZIProgram


def _check_backend(backend: str | None) -> str:
    backend = backend or "xla"
    if backend not in MESH_BACKENDS:
        raise ValueError(f"mesh backend must be one of {MESH_BACKENDS}, "
                         f"got {backend!r}")
    return backend


def _schedule_layers(rotations, m):
    """Greedy dependency-preserving layering of (i, j, theta) rotations
    given in APPLICATION order.  Returns a list of layers (lists)."""
    last = [-1] * m
    layers = []
    for (i, j, theta) in rotations:
        at = max(last[i], last[j]) + 1
        if at == len(layers):
            layers.append([])
        layers[at].append((i, j, theta))
        last[i] = last[j] = at
    return layers


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MZIMesh:
    """One orthogonal matrix as a compiled, jittable rotation-layer stack.

    Represents o = G_1^T ... G_K^T diag(signs) (the ``mzi.reconstruct``
    convention); ``apply`` computes o @ x (or o^T @ x) on the last axis
    of ``x``, broadcasting over leading batch dims.  Leading batch axes
    on the layer arrays themselves are allowed (``_stack_meshes``).
    """
    dim: int
    n_rot: int            # real MZI rotations in the program
    signs: jnp.ndarray    # (m,)
    perm: jnp.ndarray     # (L, m) int32 partner wire (self = untouched)
    ca: jnp.ndarray       # (L, m) diagonal coefficient (cos theta / 1)
    sa: jnp.ndarray       # (L, m) off-diagonal coefficient (-+ sin theta / 0)

    # -------------------------------------------------------- pytree
    def tree_flatten(self):
        return ((self.signs, self.perm, self.ca, self.sa),
                (self.dim, self.n_rot))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(aux[0], aux[1], *leaves)

    @property
    def num_rotations(self) -> int:
        return self.n_rot

    @property
    def depth(self) -> int:
        """Optical depth: rotation layers behind one another."""
        return int(self.perm.shape[-2])

    # ------------------------------------------------------- compile
    @classmethod
    def compile(cls, program: MZIProgram, dtype=None) -> "MZIMesh":
        """Layer, pad, and stack an ``MZIProgram`` into layer arrays.

        ``dtype`` defaults to float64 when jax x64 is enabled (oracle
        cross-checks), float32 otherwise (the fast runtime path).

        The stacks are stored as NUMPY arrays on purpose: compilation may
        run inside a jit/shard_map trace (``runtime.get_module`` resolves
        lazily from ``_photonic_sync``), and numpy leaves stay concrete
        there — they lower as constants in every trace that applies the
        mesh, instead of leaking one trace's tracers into the next
        (``module.py`` stores the dense params the same way).
        """
        if dtype is None:
            dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        m = program.dim
        # application order for o @ x: diag(signs) first, then G_K^T..G_1^T
        layers = _schedule_layers(list(reversed(program.rotations)), m)
        if not layers:
            layers = [[]]
        L = len(layers)
        perm = np.tile(np.arange(m, dtype=np.int32), (L, 1))
        ca = np.ones((L, m), np.float64)
        sa = np.zeros((L, m), np.float64)
        for li, layer in enumerate(layers):
            for (i, j, t) in layer:
                c, s = np.cos(t), np.sin(t)
                perm[li, i], perm[li, j] = j, i
                ca[li, i] = ca[li, j] = c
                # G^T:  y_i' = c y_i - s y_j ;  y_j' = s y_i + c y_j
                sa[li, i], sa[li, j] = -s, s
        return cls(dim=m, n_rot=len(program.rotations),
                   signs=np.asarray(program.signs, dtype),
                   perm=perm,
                   ca=np.asarray(ca, dtype),
                   sa=np.asarray(sa, dtype))

    # --------------------------------------------------------- apply
    def apply(self, x: jnp.ndarray, transpose: bool = False,
              backend: str | None = None,
              post_scale: jnp.ndarray | None = None,
              noise=None, key=None, blk_b: int = 0) -> jnp.ndarray:
        """o @ x (or o^T @ x when ``transpose``) over the last axis.

        ``backend`` selects the executor (``PhotonicsConfig.mesh_backend``):
        'xla' (default) runs one gather+FMA per layer under ``lax.scan``;
        'pallas' runs the fused VMEM-resident kernel
        (``kernels.mesh_scan``), tiled by ``blk_b`` batch rows
        (``PhotonicsConfig.blk_b``, 0 = default).  ``post_scale`` is an
        optional diagonal epilogue multiplied into the output — on the
        pallas path it is fused into the kernel's final VPU pass.

        ``noise`` (a ``pipeline.PhaseNoise``) + ``key`` inject the
        thermal/shot noise model: on the xla executor the theta drift
        perturbs the (ca, sa) coefficient stacks before the scan; the
        pallas executor draws the SAME drift model in-kernel (seeded per
        apply off the key — no perturbed stacks are materialized in
        XLA); shot noise lands on the analog output of either.  Both are
        no-ops (statically — the traced jaxpr is unchanged) when the
        stds are 0 or no key is given.

        A program with ZERO rotations (``n_rot == 0``, every layer an
        identity) skips both executors: the scan would compute
        ``1*y + 0*y[perm]`` per layer, bit-exactly ``y`` — and the theta
        drift on an identity layer is exactly eps = 0 (sign(wire - perm)
        vanishes), so the elision is bit-exact on every path.  This makes
        the exact-identity ONN (bits <= 2) mesh fidelity as cheap as the
        behavioral transfer function.
        """
        perm, ca, sa = self.perm, self.ca, self.sa
        k_theta = k_shot = None
        if noise is not None and noise.enabled and key is not None:
            k_theta, k_shot = jax.random.split(key)
        backend = _check_backend(backend)
        if self.n_rot == 0:
            dt = jnp.result_type(x.dtype, self.ca.dtype)
            y = x.astype(dt) * self.signs.astype(dt)
            if post_scale is not None:
                y = y * post_scale.astype(dt)
            return y if k_shot is None else noise.shot(k_shot, y)
        if backend == "pallas":
            from ..kernels.mesh_scan import mesh_scan
            theta_std, seed = 0.0, None
            if k_theta is not None and noise.theta_drift_std > 0.0:
                theta_std = noise.theta_drift_std
                seed = jax.random.bits(k_theta, (), jnp.uint32)
            y = mesh_scan(self.signs, perm, ca, sa, x,
                          transpose=transpose, post_scale=post_scale,
                          blk_b=blk_b, theta_std=theta_std, seed=seed)
            return y if k_shot is None else noise.shot(k_shot, y)
        if k_theta is not None:
            ca, sa = noise.perturb(k_theta, perm, ca, sa)
        dt = jnp.result_type(x.dtype, self.ca.dtype)
        y = x.astype(dt)
        if not transpose:
            y = y * self.signs.astype(dt)
        # the transpose applies each G instead of G^T (sa sign flips) with
        # the layer order reversed
        sgn = jnp.asarray(-1.0 if transpose else 1.0, dt)

        def body(y, layer):
            perm, ca, sa = layer
            y = (ca.astype(dt) * y
                 + sgn * sa.astype(dt) * jnp.take(y, perm, axis=-1))
            return y, None

        y, _ = lax.scan(body, y, (perm, ca, sa), reverse=transpose)
        if transpose:
            y = y * self.signs.astype(dt)
        if post_scale is not None:
            y = y * post_scale.astype(dt)
        return y if k_shot is None else noise.shot(k_shot, y)

    def matrix(self) -> jnp.ndarray:
        """Rebuild the dense orthogonal matrix (jax ``mzi.reconstruct``)."""
        return self.apply(jnp.eye(self.dim, dtype=self.ca.dtype)).T


def reconstruct(program: MZIProgram, dtype=None) -> jnp.ndarray:
    """Drop-in jax counterpart of ``mzi.reconstruct``."""
    return MZIMesh.compile(program, dtype).matrix()


def _stack_meshes(meshes):
    """Stack same-dim MZIMesh programs along a leading block axis, padding
    every program to the deepest layer count with identity layers.
    Numpy in, numpy out (trace-safe, see ``MZIMesh.compile``)."""
    dim = meshes[0].dim
    assert all(m.dim == dim for m in meshes)
    L = max(m.perm.shape[0] for m in meshes)

    def pad(mesh):
        pl = L - mesh.perm.shape[0]
        ident = np.tile(np.arange(dim, dtype=mesh.perm.dtype), (pl, 1))
        return (np.concatenate([mesh.perm, ident]),
                np.concatenate([mesh.ca,
                                np.ones((pl, dim), mesh.ca.dtype)]),
                np.concatenate([mesh.sa,
                                np.zeros((pl, dim), mesh.sa.dtype)]))

    padded = [pad(m) for m in meshes]
    return MZIMesh(
        dim=dim,
        n_rot=sum(m.n_rot for m in meshes),
        signs=np.stack([m.signs for m in meshes]),
        perm=np.stack([p[0] for p in padded]),
        ca=np.stack([p[1] for p in padded]),
        sa=np.stack([p[2] for p in padded]))


def _apply_stacked(stacked: MZIMesh, x: jnp.ndarray, x_block_axis: bool,
                   backend: str | None = None,
                   post_scale: jnp.ndarray | None = None,
                   noise=None, key=None, blk_b: int = 0):
    """Apply a stacked mesh over its block axis.  ``x`` is shared across
    blocks (tall layers) or carries its own block axis at -2 (wide
    layers).  ``post_scale`` (B, dim) is each block's diagonal epilogue
    (fused in-kernel on the pallas backend).  Returns (..., B, dim).

    The pallas backend runs ONE ``mesh_scan_blocks`` launch with the
    block axis folded into the kernel grid (theta drift drawn in-kernel
    from per-block seeds); the xla backend vmaps the per-block scan,
    splitting the key so every block draws independent noise.  A stack
    with zero rotations total (every block's every layer an identity)
    skips both executors bit-exactly — see ``MZIMesh.apply``.
    """
    n_blocks = stacked.signs.shape[0]
    k_theta = k_shot = None
    if noise is not None and noise.enabled and key is not None:
        k_theta, k_shot = jax.random.split(key)
    if stacked.n_rot == 0:
        _check_backend(backend)
        dt = jnp.result_type(x.dtype, stacked.ca.dtype)
        y = x.astype(dt)
        if not x_block_axis:
            y = y[..., None, :]
        y = y * stacked.signs.astype(dt)
        if post_scale is not None:
            y = y * post_scale.astype(dt)
        return y if k_shot is None else noise.shot(k_shot, y)
    if _check_backend(backend) == "pallas":
        from ..kernels.mesh_scan import mesh_scan_blocks
        theta_std, seeds = 0.0, None
        if k_theta is not None and noise.theta_drift_std > 0.0:
            theta_std = noise.theta_drift_std
            seeds = jax.random.bits(k_theta, (n_blocks,), jnp.uint32)
        y = mesh_scan_blocks(stacked.signs, stacked.perm, stacked.ca,
                             stacked.sa, x, x_block_axis=x_block_axis,
                             post_scale=post_scale, blk_b=blk_b,
                             theta_std=theta_std, seeds=seeds)
        return y if k_shot is None else noise.shot(k_shot, y)
    keys = None
    if noise is not None and noise.enabled and key is not None:
        keys = jax.random.split(key, n_blocks)

    def one(signs, perm, ca, sa, xb, ps, k):
        return MZIMesh(stacked.dim, 1, signs, perm, ca, sa).apply(
            xb, backend=backend, post_scale=ps, noise=noise, key=k)

    out = jax.vmap(one,
                   in_axes=(0, 0, 0, 0, -2 if x_block_axis else None,
                            None if post_scale is None else 0,
                            None if keys is None else 0),
                   out_axes=0)(stacked.signs, stacked.perm, stacked.ca,
                               stacked.sa, x, post_scale, keys)
    return jnp.moveaxis(out, 0, -2)


# ---------------- compiled ONN hardware programs (layer level) ----------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SVDLayerProgram:
    """W = U Sigma V^T on two meshes + one diagonal column (paper eq. 1)."""
    shape: tuple
    u: MZIMesh
    v: MZIMesh
    sigma: jnp.ndarray
    b: jnp.ndarray

    def tree_flatten(self):
        return ((self.u, self.v, self.sigma, self.b), self.shape)

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(shape, *leaves)

    @property
    def num_mzis(self) -> int:
        return (self.u.num_rotations + self.v.num_rotations
                + int(self.sigma.shape[0]))

    def apply(self, x: jnp.ndarray, backend: str | None = None,
              noise=None, key=None, blk_b: int = 0) -> jnp.ndarray:
        kv = ku = None
        if key is not None:
            kv, ku = jax.random.split(key)
        m, _ = self.shape
        k = self.sigma.shape[0]
        z = self.v.apply(x, transpose=True, backend=backend,
                         noise=noise, key=kv, blk_b=blk_b)[..., :k]
        z = z * self.sigma
        if m > k:
            z = jnp.concatenate(
                [z, jnp.zeros(z.shape[:-1] + (m - k,), z.dtype)], axis=-1)
        return self.u.apply(z, backend=backend, noise=noise, key=ku,
                            blk_b=blk_b) + self.b


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ApproxLayerProgram:
    """Sigma_a U_a blocks (paper eq. 4): one mesh + diag column per block."""
    shape: tuple
    meshes: MZIMesh          # stacked along a leading block axis
    d: jnp.ndarray           # (n_blocks, s)
    b: jnp.ndarray

    def tree_flatten(self):
        return ((self.meshes, self.d, self.b), self.shape)

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(shape, *leaves)

    @property
    def num_mzis(self) -> int:
        n_blocks, s = self.d.shape
        return self.meshes.num_rotations + n_blocks * s

    def apply(self, x: jnp.ndarray, backend: str | None = None,
              noise=None, key=None, blk_b: int = 0) -> jnp.ndarray:
        # the Sigma_a diagonal rides as the meshes' fused epilogue (the
        # pallas kernel applies it in VMEM before the HBM write)
        m, n = self.shape
        s = min(m, n)
        if m >= n:
            ys = _apply_stacked(self.meshes, x, x_block_axis=False,
                                backend=backend, post_scale=self.d,
                                noise=noise, key=key, blk_b=blk_b)
            y = ys.reshape(x.shape[:-1] + (m,))
        else:
            xs = x.reshape(x.shape[:-1] + (n // s, s))
            ys = _apply_stacked(self.meshes, xs, x_block_axis=True,
                                backend=backend, post_scale=self.d,
                                noise=noise, key=key, blk_b=blk_b)
            y = jnp.sum(ys, axis=-2)
        return y + self.b


def compile_layer(hw_layer, dtype=None):
    """Compile one ``onn.map_to_hardware`` layer dict to a jittable program.
    Leaves are numpy (trace-safe constants, see ``MZIMesh.compile``)."""
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if hw_layer["kind"] == "svd":
        return SVDLayerProgram(
            shape=tuple(hw_layer["shape"]),
            u=MZIMesh.compile(hw_layer["u"], dtype),
            v=MZIMesh.compile(hw_layer["v"], dtype),
            sigma=np.asarray(hw_layer["sigma"], dtype),
            b=np.asarray(hw_layer["b"], dtype))
    blocks = hw_layer["blocks"]
    return ApproxLayerProgram(
        shape=tuple(hw_layer["shape"]),
        meshes=_stack_meshes([MZIMesh.compile(blk["u"], dtype)
                              for blk in blocks]),
        d=np.stack([np.asarray(blk["d"], dtype) for blk in blocks]),
        b=np.asarray(hw_layer["b"], dtype))


def compile_hardware(hw, dtype=None):
    """Compile the full ``onn.map_to_hardware`` program list."""
    return [compile_layer(layer, dtype) for layer in hw]


def apply_hardware(programs, a: jnp.ndarray, cfg,
                   backend: str | None = None,
                   noise=None, key=None, blk_b: int = 0) -> jnp.ndarray:
    """Jittable forward pass through the compiled MZI meshes — the fast
    counterpart of ``onn.apply_hardware`` (the numpy oracle).  ``backend``
    selects the layer executor (``PhotonicsConfig.mesh_backend``) and
    ``blk_b`` its batch tile; ``noise`` + ``key`` thread the PhaseNoise
    model into every layer's meshes (one key per layer, folded off
    ``key``)."""
    x = a / jnp.asarray(cfg.in_scale, programs[0].b.dtype)
    for li, prog in enumerate(programs):
        k = None if key is None else jax.random.fold_in(key, li)
        x = prog.apply(x, backend=backend, noise=noise, key=k, blk_b=blk_b)
        if li < len(programs) - 1:
            x = jax.nn.relu(x)
    return x * cfg.out_scale
