"""ONNModule: one in-network ONN as a device-ready object.

Bundles the ``ONNConfig``, the trained dense parameters, and (lazily)
the phase-programmed mesh emulation of those parameters, behind the
three fidelity levels the collective engine exposes:

    module.apply(a)        dense jax forward pass (fidelity='onn')
    module.apply_mesh(a)   compiled MZI-mesh emulator (fidelity='mesh')
    module.symbols(a, ...) either of the above + transceiver readout

``map_to_hardware`` (Givens programming) runs once, at first use; the
compiled ``mesh.py`` programs are cached on the module and jit-friendly
(closed over as constants inside ``sync_gradients``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import mesh as mesh_mod
from . import onn as onn_mod
from .encoding import num_symbols
from .onn import ONNConfig, Transceiver


@dataclasses.dataclass
class ONNModule:
    cfg: ONNConfig
    params: list                       # dense layer dicts ({"w", "b"})
    transceiver: Transceiver = dataclasses.field(default_factory=Transceiver)
    _programs: list | None = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------ constructors
    @classmethod
    def init(cls, cfg: ONNConfig, rng) -> "ONNModule":
        return cls(cfg, onn_mod.init_params(cfg, rng))

    @classmethod
    def from_params(cls, cfg: ONNConfig, params) -> "ONNModule":
        # numpy storage: modules may be resolved inside a jit/shard_map
        # trace, where jnp constructors would produce tracers; numpy
        # params stay concrete and lower as constants
        return cls(cfg, [{"w": np.asarray(l["w"], np.float32),
                          "b": np.asarray(l["b"], np.float32)}
                         for l in params])

    @classmethod
    def exact_identity(cls, bits: int, n_servers: int) -> "ONNModule":
        """Analytically exact ONN for the single-symbol transfer function.

        With M = num_symbols(bits) == 1 and K = 1 the behavioural target
        Q(mean) is just round(A), so a (1, 4, 1) identity network
        + transceiver rounding IS the oracle — 100% accuracy by
        construction, no training needed.

        The weights are the WIRE-EXACT form: the value rides a single
        waveguide (w1 = e1, w2 = e1^T), whose SVD factors are exact 0/1
        matrices, so Givens programming emits ZERO rotations and the mesh
        emulator (both executors) passes the value through exactly — the
        only float ops left are the in/out scale pair a/3 * 3, which is
        exact at every half-integer of [0, 2^B - 2] under both division
        lowerings (true divide and XLA's multiply-by-reciprocal).  PAM4
        decision ties (A == k + 0.5, even-N meshes and the carry-cascade's
        quarter grids) therefore resolve exactly like ``jnp.round``'s
        round-half-even — bit-identical to the behavioral backend — where
        the previous all-ones weights left ties at the mercy of ~1 ulp
        Givens rotation noise.  (ReLU stays transparent: inputs are
        >= 0, and the eq.-10 carry keeps merged values >= 0.)
        """
        if num_symbols(bits) != 1:
            raise ValueError(
                f"exact identity ONN needs a single PAM4 symbol per value "
                f"(bits <= 2), got bits={bits}")
        cfg = ONNConfig(structure=(1, 4, 1), approx_layers=(), bits=bits,
                        n_servers=n_servers, k_inputs=1)
        w1 = np.zeros((4, 1), np.float32)
        w1[0, 0] = 1.0
        params = [{"w": w1, "b": np.zeros((4,), np.float32)},
                  {"w": w1.T.copy(), "b": np.zeros((1,), np.float32)}]
        return cls(cfg, params)

    @classmethod
    def train(cls, cfg: ONNConfig, epochs: int, seed: int = 0,
              samples: int = 0, **train_kw) -> "ONNModule":
        """Hardware-aware training (cayley mode: constraint-exact)."""
        from . import dataset, training
        if samples:
            a, t = dataset.sampled_dataset(
                cfg, np.random.default_rng(seed), samples)
        else:
            a, t = dataset.full_dataset(cfg)
        tcfg = training.TrainConfig(
            epochs=epochs, e1=int(epochs * 0.8), mode="cayley", seed=seed,
            **train_kw)
        params, _ = training.train(cfg, tcfg, a, t, eval_every=0)
        return cls.from_params(cfg, params)

    # ------------------------------------------------------ fidelities
    def apply(self, a: jnp.ndarray) -> jnp.ndarray:
        """Dense forward pass -> analog outputs in symbol units."""
        return onn_mod.apply(self.params, a, self.cfg)

    @property
    def programs(self) -> list:
        """Compiled MZI-mesh layer programs (Givens-programmed once)."""
        if self._programs is None:
            hw = onn_mod.map_to_hardware(self.params, self.cfg)
            self._programs = mesh_mod.compile_hardware(hw)
        return self._programs

    def apply_mesh(self, a: jnp.ndarray, backend: str | None = None,
                   noise=None, key=None, blk_b: int = 0) -> jnp.ndarray:
        """Forward pass through the phase-programmed mesh emulator.
        ``backend`` picks the layer executor (xla scan | fused pallas)
        and ``blk_b`` the pallas batch tile; ``noise`` + ``key`` inject
        the PhaseNoise model (pipeline.py)."""
        return mesh_mod.apply_hardware(self.programs, a, self.cfg,
                                       backend=backend, noise=noise, key=key,
                                       blk_b=blk_b)

    def symbols(self, a: jnp.ndarray, fidelity: str = "onn",
                mesh_backend: str | None = None,
                noise=None, key=None, blk_b: int = 0) -> jnp.ndarray:
        """Analog forward pass + transceiver readout -> PAM4 symbols."""
        out = (self.apply_mesh(a, backend=mesh_backend, noise=noise, key=key,
                               blk_b=blk_b)
               if fidelity == "mesh" else self.apply(a))
        return self.transceiver.readout(out)

    # ------------------------------------------------------ diagnostics
    def accuracy(self, a, tgt) -> float:
        from . import training
        return training.accuracy(self.params, np.asarray(a), np.asarray(tgt),
                                 self.cfg)

    def area_ratio(self) -> float:
        return onn_mod.area_ratio(self.cfg)
