"""Stage-composable photonic sync pipeline (paper III-A / III-C).

One level of the OptINC fabric — what ``collectives.backends`` used to
inline as ``_photonic_sync`` — is five small jittable stages:

    Encode      offset-binary codes -> PAM4 symbols -> grouped unit-P
                input values (eq. 2); an incoming eq.-10 carry rides on
                the least-significant group
    Preprocess  unit P: exact integer psum over the level's mesh axes / N
    MeshApply   the in-network ONN — trained dense forward ('onn') or the
                phase-programmed MZI mesh emulator ('mesh'), with the
                PhaseNoise model on the programmed thetas / analog outputs
    Readout     transceiver decision stage; with ``emit_carry`` the eq.-10
                decimal part d = analog value - decoded value leaves the
                level as ``Carry.frac``
    Decode      PAM4 symbols -> offset-binary integer codes

Each stage is a frozen dataclass with ``apply(carry, key) -> carry``; a
``SyncPipeline`` folds a per-stage key off the level key and runs the
stages in order.  The single-level optinc backend is ONE pipeline over
``cfg.axes``; the two-level carry-cascade is TWO chained pipelines — the
level-0 (intra-pod) pipeline emits its carry, the level-1 (inter-pod)
pipeline consumes it — so both photonic fidelities run the ONN/mesh
emulator at every cascade level (closing the last behavioral-only gap).

Carry-symbol semantics (eq. 10): a level that emits a carry reads the
decimal part d off its ANALOG outputs (``encoding.symbol_value``), i.e.
the same physical quantity its extra, higher-resolution PAM4 symbol
would encode — so mesh noise and ONN inaccuracy propagate into d
physically, while on a 100%-accuracy ONN ``decoded + d`` equals the
exact unit-P average and the chained pipelines reproduce the one-shot
eq. 8 quantization bit-exactly (the behavioral cascade).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .encoding import group_symbols, pam4_decode, pam4_encode, symbol_value


class Carry(NamedTuple):
    """What flows between stages: the payload and the eq.-10 carry."""
    data: jnp.ndarray              # stage payload (codes/values/symbols)
    frac: jnp.ndarray | None = None  # decimal carry d, in value units


# --------------------------------------------------------------- noise

@dataclasses.dataclass(frozen=True)
class PhaseNoise:
    """Thermal drift + shot noise on the emulated MZI mesh.

    ``theta_drift_std`` perturbs every programmed phase theta -> theta +
    eps with one eps ~ N(0, std) PER ROTATION and apply (an MZI has one
    thermal phase shifter, so its two wires must rotate coherently);
    ``shot_noise_std`` adds white photodetector noise to the analog
    outputs after the optical path.  Both draw from the key threaded
    through ``MZIMesh.apply`` (derived from the per-step sync key), so
    noise is reproducible and identical across processes.  A zero std
    disables its term STATICALLY — the zero-noise path traces exactly
    the jaxpr of the noise-free emulator, keeping it bit-exact.
    """
    theta_drift_std: float = 0.0
    shot_noise_std: float = 0.0

    @property
    def enabled(self) -> bool:
        return self.theta_drift_std > 0.0 or self.shot_noise_std > 0.0

    @classmethod
    def from_config(cls, ph) -> "PhaseNoise | None":
        """PhotonicsConfig -> PhaseNoise, or None when both stds are 0."""
        noise = cls(theta_drift_std=ph.theta_drift_std,
                    shot_noise_std=ph.shot_noise_std)
        return noise if noise.enabled else None

    def perturb(self, key, perm, ca, sa):
        """Drift the (L, m) coefficient stacks of a compiled mesh.

        A rotation on wires (i, j) stores ca = cos(theta) on both wires
        and sa = -+ sin(theta); drawing one gaussian per wire and
        symmetrizing over the partner permutation gives one delta per
        rotation, and the antisymmetric sign(wire - partner) assignment
        turns the per-wire update
            ca' = ca cos(eps) - sa sin(eps)
            sa' = sa cos(eps) + ca sin(eps)
        into a coherent theta -> theta + delta on both wires.  Untouched
        wires (perm == self) get eps = 0 exactly, so identity padding
        stays identity.
        """
        if self.theta_drift_std <= 0.0 or key is None:
            return ca, sa
        g = jax.random.normal(key, perm.shape, ca.dtype)
        # (g_i + g_j)/sqrt(2) of two iid N(0,1) draws is N(0,1) again, so
        # the per-rotation drift really has std = theta_drift_std
        delta = (0.5 ** 0.5) * (g + jnp.take_along_axis(g, perm, axis=-1))
        wires = jnp.arange(perm.shape[-1], dtype=perm.dtype)
        sign = jnp.sign(wires - perm).astype(ca.dtype)
        eps = jnp.asarray(self.theta_drift_std, ca.dtype) * delta * sign
        ce, se = jnp.cos(eps), jnp.sin(eps)
        return ca * ce - sa * se, sa * ce + ca * se

    def shot(self, key, y):
        """Additive photodetector noise on the analog mesh outputs."""
        if self.shot_noise_std <= 0.0 or key is None:
            return y
        return y + jnp.asarray(self.shot_noise_std, y.dtype) * \
            jax.random.normal(key, y.shape, y.dtype)


# --------------------------------------------------------------- stages

@dataclasses.dataclass(frozen=True)
class Encode:
    """Offset-binary integer codes -> grouped unit-P input values.

    ``carry.data``: (L,) int codes in [0, 2^B - 2].  An incoming eq.-10
    carry (``carry.frac``, value units) is merged into the
    least-significant group — the higher-resolution extra PAM4 symbol of
    the cascade's level-1 output, weight (4^g)^0 = 1.
    """
    bits: int
    k_inputs: int

    def apply(self, carry: Carry, key) -> Carry:
        sym = pam4_encode(carry.data, self.bits)
        vals = group_symbols(sym, self.bits, self.k_inputs)
        vals = vals.astype(jnp.float32)
        if carry.frac is not None:
            vals = vals.at[..., -1].add(carry.frac)
        return Carry(vals)


@dataclasses.dataclass(frozen=True)
class Preprocess:
    """Unit P, distributed: exact integer psum over the level's axes / N.

    Each peer groups its own symbols locally (``Encode``); the fabric's
    average is an exact integer psum / N — bit-identical to gathering all
    N symbol streams and taking ``encoding.preprocess``'s mean, without
    the N x memory blowup.
    """
    axes: tuple

    def apply(self, carry: Carry, key) -> Carry:
        total = carry.data
        n = 1
        for ax in self.axes:
            total = lax.psum(total, ax)
            n *= lax.axis_size(ax)
        return Carry(total / n)


@dataclasses.dataclass(frozen=True)
class MeshApply:
    """The in-network ONN: dense forward pass ('onn') or the MZI mesh
    emulator ('mesh', xla scan or fused pallas kernel), with the
    PhaseNoise model injected into ``MZIMesh.apply``."""
    module: object                  # ONNModule
    fidelity: str = "onn"
    mesh_backend: str | None = None
    noise: PhaseNoise | None = None
    blk_b: int = 0                  # pallas batch tile (0 = default)

    def apply(self, carry: Carry, key) -> Carry:
        if self.fidelity == "mesh":
            y = self.module.apply_mesh(carry.data, backend=self.mesh_backend,
                                       noise=self.noise, key=key,
                                       blk_b=self.blk_b)
        else:
            y = self.module.apply(carry.data)
        return Carry(y)


@dataclasses.dataclass(frozen=True)
class Readout:
    """Transceiver decision stage (paper's ADC): analog symbols -> PAM4.

    With ``emit_carry`` (a cascade level that is not the last), the
    eq.-10 decimal part leaves as ``frac``: the difference between the
    ANALOG value the ONN computed (``symbol_value``, what the extra
    higher-resolution output symbol would carry) and the decoded integer
    decision.  decoded + frac == the analog value, so nothing is lost
    between levels; noise/ONN error in the analog value propagates.
    """
    transceiver: object             # onn.Transceiver
    emit_carry: bool = False

    def apply(self, carry: Carry, key) -> Carry:
        sym = self.transceiver.readout(carry.data)
        frac = None
        if self.emit_carry:
            frac = (symbol_value(carry.data)
                    - pam4_decode(sym).astype(jnp.float32))
        return Carry(sym, frac)


@dataclasses.dataclass(frozen=True)
class Decode:
    """PAM4 symbols -> offset-binary integer codes; an outgoing carry
    stays attached for the next level's Encode."""

    def apply(self, carry: Carry, key) -> Carry:
        return Carry(pam4_decode(carry.data), carry.frac)


# ------------------------------------------------------------- pipeline

@dataclasses.dataclass(frozen=True)
class SyncPipeline:
    """An ordered stage tuple for ONE reduction level of the fabric."""
    stages: tuple

    def run(self, data: jnp.ndarray, key=None,
            frac: jnp.ndarray | None = None) -> Carry:
        """Thread ``Carry(data, frac)`` through the stages.  Each stage
        receives its own key (folded off ``key`` by stage index), so
        stage-level randomness (PhaseNoise) is reproducible per level."""
        carry = Carry(data, frac)
        for i, stage in enumerate(self.stages):
            k = None if key is None else jax.random.fold_in(key, i)
            carry = stage.apply(carry, k)
        return carry


def level_pipeline(module, bits: int, axes: tuple, fidelity: str = "onn",
                   mesh_backend: str | None = None,
                   noise: PhaseNoise | None = None,
                   emit_carry: bool = False, blk_b: int = 0) -> SyncPipeline:
    """The canonical Encode -> Preprocess -> MeshApply -> Readout -> Decode
    pipeline for one reduction level over ``axes``."""
    return SyncPipeline(stages=(
        Encode(bits=bits, k_inputs=module.cfg.k_inputs),
        Preprocess(axes=tuple(axes)),
        MeshApply(module=module, fidelity=fidelity,
                  mesh_backend=mesh_backend, noise=noise, blk_b=blk_b),
        Readout(transceiver=module.transceiver, emit_carry=emit_carry),
        Decode(),
    ))
