"""PhotonicsConfig: the runtime fidelity knob of the optical subsystem.

One frozen, JSON-round-trippable dataclass describes how faithfully the
collective engine emulates the in-network ONN:

  fidelity='behavioral'  Q(mean) computed directly in the integer domain
                         (paper eq. 3) — the fastest path, bit-exact by
                         definition.
  fidelity='onn'         the PAM4 symbol stream runs through the trained
                         dense ONN (onn.apply + transceiver readout), so
                         the learned approximation of eq. 3 sits in the
                         training loop.
  fidelity='mesh'        the phase-programmed MZI mesh emulator itself
                         (mesh.py: Givens layers under lax.scan) computes
                         every linear layer — emulated hardware in the
                         loop, still jit-compiled.

``SyncConfig.photonics`` carries this config into the optinc backend;
``RunSpec`` threads it from ``--fidelity`` (launch/train.py).
"""
from __future__ import annotations

import dataclasses

FIDELITIES = ("behavioral", "onn", "mesh")

PARAM_SOURCES = ("auto", "exact", "results", "train")

# how fidelity='mesh' executes the compiled rotation-layer stacks:
#   'xla'     one gather+FMA per layer under lax.scan (photonics.mesh)
#   'pallas'  the fused VMEM-resident kernel (kernels.mesh_scan): all L
#             layers applied per batch tile in one pallas_call, compiled
#             on TPU / interpreted elsewhere (resolve_interpret)
MESH_BACKENDS = ("xla", "pallas")


def resolve_interpret(flag: bool | None = None) -> bool:
    """Pallas ``interpret`` auto-detection: compiled on TPU, interpreted
    everywhere else.  An explicit True/False always wins."""
    if flag is not None:
        return bool(flag)
    import jax
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class PhotonicsConfig:
    """Optical-subsystem runtime knobs (all JSON-serializable).

    ``structure``/``approx_layers`` describe the in-network ONN used by the
    ``onn``/``mesh`` fidelities; ``()`` derives a default from the sync bit
    width (see ``runtime.default_structure``).  ``params`` selects where
    the trained weights come from:

      'exact'    analytically exact identity ONN — only possible when the
                 transfer function is linear, i.e. one PAM4 symbol per
                 value and one ONN input (bits <= 2, k_inputs == 1)
      'results'  results/scenario1*_params.pkl (quickstart --onn output)
      'train'    hardware-aware training at resolve time (train_epochs)
      'auto'     exact if possible, else results, else error with guidance

    ``theta_drift_std`` / ``shot_noise_std`` parameterize the PhaseNoise
    model of the mesh emulator (``pipeline.PhaseNoise``): a per-apply
    thermal drift on every programmed MZI phase (theta -> theta + eps,
    eps ~ N(0, theta_drift_std)) and white photodetector noise on the
    analog outputs.  Both are seeded from the per-step sync key, so runs
    are reproducible and identical across processes; 0.0 disables each
    term statically (the zero-noise path is bit-exact with the
    noise-free emulator).  Only meaningful at fidelity='mesh'.
    """
    fidelity: str = "behavioral"
    structure: tuple = ()          # () = auto from bits/k_inputs
    approx_layers: tuple = ()
    k_inputs: int = 4              # K (clamped to the symbol count M)
    params: str = "auto"           # auto | exact | results | train
    train_epochs: int = 0          # 'train' source budget (0 = refuse)
    seed: int = 0
    mesh_backend: str = "xla"      # fidelity='mesh' executor: xla | pallas
    blk_b: int = 0                 # pallas batch tile (rows/VMEM tile);
    #                                0 = kernel default (128).  Tune with
    #                                benchmarks/mesh_emulation.py --blk-b-sweep
    theta_drift_std: float = 0.0   # thermal drift on programmed phases (rad)
    shot_noise_std: float = 0.0    # additive noise on analog outputs

    def __post_init__(self):
        if self.fidelity not in FIDELITIES:
            raise ValueError(f"fidelity must be one of {FIDELITIES}, "
                             f"got {self.fidelity!r}")
        if self.params not in PARAM_SOURCES:
            raise ValueError(f"params must be one of {PARAM_SOURCES}, "
                             f"got {self.params!r}")
        if self.mesh_backend not in MESH_BACKENDS:
            raise ValueError(f"mesh_backend must be one of {MESH_BACKENDS}, "
                             f"got {self.mesh_backend!r}")
        if self.blk_b < 0 or self.blk_b % 8:
            raise ValueError(
                f"blk_b must be a multiple of the 8-row sublane tile "
                f"(0 = auto), got {self.blk_b!r}")
        if self.theta_drift_std < 0.0 or self.shot_noise_std < 0.0:
            raise ValueError(
                f"noise stds must be >= 0, got theta_drift_std="
                f"{self.theta_drift_std!r} shot_noise_std="
                f"{self.shot_noise_std!r}")
