"""Matrix approximation W_s ~= Sigma_a U_a (paper eq. 4-6, Fig. 4).

A rectangular weight W (m x n) is partitioned into square s x s submatrices
along its longer dimension (s = min(m, n)); each submatrix is approximated by

    W_a = Sigma_a @ U_a,   U_a = U_s V_s^T  (orthogonal Procrustes),
    d_i = argmin_d ||W_s^i - d * U_a^i||^2 = <W_s^i, U_a^i>   (U_a rows unit)

which halves the MZI count (one mesh + one diagonal column instead of two
meshes + a column). Implemented in jnp so it can run inside the training
loop as a periodic projection (paper III-B).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def block_size(m: int, n: int) -> int:
    s = min(m, n)
    if m % s or n % s:
        raise ValueError(f"matrix {m}x{n} not partitionable into {s}x{s} blocks")
    return s


def approx_block(ws: jnp.ndarray) -> jnp.ndarray:
    """Sigma_a U_a approximation of one square block (eq. 4-6)."""
    u, _, vt = jnp.linalg.svd(ws, full_matrices=False)
    ua = u @ vt                      # orthogonal Procrustes solution
    d = jnp.sum(ws * ua, axis=1)     # least-squares row scales (rows unit norm)
    return d[:, None] * ua


def approx_block_factors(ws: np.ndarray):
    """Numpy variant returning (d, U_a) for hardware mapping."""
    u, _, vt = np.linalg.svd(ws, full_matrices=False)
    ua = u @ vt
    d = np.sum(ws * ua, axis=1)
    return d, ua


def approx_matrix(w: jnp.ndarray) -> jnp.ndarray:
    """Partition (horizontally or vertically, Fig. 4) and approximate every
    block. Differentiable-safe (used as a projection, not in the loss)."""
    m, n = w.shape
    s = block_size(m, n)
    if m == n:
        return approx_block(w)
    if m > n:   # tall: horizontal cuts -> stack of (s x n=s) blocks
        blocks = w.reshape(m // s, s, n)
        out = jnp.stack([approx_block(blocks[i]) for i in range(m // s)])
        return out.reshape(m, n)
    # wide: vertical cuts
    blocks = w.reshape(m, n // s, s).transpose(1, 0, 2)
    out = jnp.stack([approx_block(blocks[i]) for i in range(n // s)])
    return out.transpose(1, 0, 2).reshape(m, n)


def approx_error(w: jnp.ndarray) -> float:
    """Relative Frobenius error of the approximation (diagnostic)."""
    wa = approx_matrix(w)
    return float(jnp.linalg.norm(w - wa) / jnp.maximum(jnp.linalg.norm(w), 1e-30))
