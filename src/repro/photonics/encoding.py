"""PAM4 gradient encoding/decoding and block quantization (paper eq. 2-3).

A B-bit quantized gradient value ``u`` (offset-binary unsigned integer) is
encoded into ``M = ceil(B/2)`` PAM4 symbols (2 bits each, eq. 2):

    I^(i) = floor(u / 4^(M-i)) mod 4,   i = 1..M   (i=1 is the MSB symbol)

The OptINC behavioural target (eq. 3) is the quantized average

    G_bar = Q( (1/N) * sum_n G_n )      with Q = round-to-nearest.

Quantization is global/block max-abs scaling to signed B-bit, stored in
offset-binary so that optical amplitudes are non-negative.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


def num_symbols(bits: int) -> int:
    """M = ceil(B/2) PAM4 symbols per B-bit value."""
    return (bits + 1) // 2


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Block quantization spec. ``block`` is the flattened block size; 0 means
    a single global scale (the paper's 'global block quantization')."""
    bits: int = 8
    block: int = 0

    @property
    def levels(self) -> int:
        # symmetric signed range [-levels, +levels]
        return 2 ** (self.bits - 1) - 1

    @property
    def offset(self) -> int:
        return 2 ** (self.bits - 1)


def _block_view(x: jnp.ndarray, block: int) -> jnp.ndarray:
    flat = x.reshape(-1)
    if block <= 0:
        return flat.reshape(1, -1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block)


def compute_scale(g: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Per-block max-abs scale, shape (num_blocks,)."""
    blocks = _block_view(g, spec.block)
    s = jnp.max(jnp.abs(blocks), axis=1)
    return jnp.maximum(s, jnp.finfo(jnp.float32).tiny)


def quantize(g: jnp.ndarray, spec: QuantSpec, scale: jnp.ndarray | None = None):
    """Float gradient -> offset-binary uint integers in [0, 2^B - 2].

    Returns (u, scale). ``u`` has g's shape, int32.
    """
    g = g.astype(jnp.float32)
    if scale is None:
        scale = compute_scale(g, spec)
    blocks = _block_view(g, spec.block)
    q = jnp.round(blocks / scale[:, None] * spec.levels)
    q = jnp.clip(q, -spec.levels, spec.levels).astype(jnp.int32)
    u = q + spec.levels  # offset binary, in [0, 2*levels] = [0, 2^B - 2]
    u = u.reshape(-1)[: g.size].reshape(g.shape)
    return u, scale


def dequantize(u: jnp.ndarray, scale: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    blocks = _block_view(u.astype(jnp.float32) - spec.levels, spec.block)
    g = blocks * (scale[:, None] / spec.levels)
    return g.reshape(-1)[: u.size].reshape(u.shape)


def pam4_encode(u: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Offset-binary ints -> PAM4 symbols, appended axis of size M (eq. 2).

    Symbol i=0 is the most significant (paper's i=1).
    """
    m = num_symbols(bits)
    shifts = jnp.arange(m - 1, -1, -1, dtype=jnp.int32)  # 4^(M-i)
    sym = (u[..., None] // (4 ** shifts)) % 4
    return sym.astype(jnp.int32)


def pam4_decode(sym: jnp.ndarray) -> jnp.ndarray:
    """PAM4 symbols (last axis = M, MSB first) -> offset-binary ints."""
    m = sym.shape[-1]
    weights = 4 ** jnp.arange(m - 1, -1, -1, dtype=jnp.int32)
    return jnp.sum(sym.astype(jnp.int32) * weights, axis=-1)


def qmean(u_stack: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
    """Eq. (3): Q(mean over server axis 0) in the integer domain."""
    if n is None:
        n = u_stack.shape[0]
    total = jnp.sum(u_stack.astype(jnp.int32), axis=0)
    return jnp.round(total.astype(jnp.float32) / n).astype(jnp.int32)


def expected_avg_symbols(sym_stack: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Servers' PAM4 symbols (N, ..., M) -> symbols of Q(mean) — the ONN's
    exact behavioural target."""
    u = pam4_decode(sym_stack)
    return pam4_encode(qmean(u), bits)


# ------------------------- preprocessing unit P -------------------------

def preprocess_group_size(bits: int, k: int) -> int:
    """g = ceil(M/K): number of PAM4 symbols merged per ONN input."""
    m = num_symbols(bits)
    return math.ceil(m / k)


def group_symbols(sym: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Unit P grouping for ONE symbol stream: merge each group of g
    consecutive PAM4 symbols into a base-4 value.

    sym: (..., M) -> (..., K) int values in [0, 4^g - 1].  Each server can
    compute this locally; the P unit's output is the mean over servers
    (``preprocess``), which distributed emulations may equivalently form
    as an exact integer psum / N (the values are small integers, exact in
    float32).
    """
    m = sym.shape[-1]
    g = preprocess_group_size(bits, k)
    pad = k * g - m
    if pad:
        # zero-pad on the MSB side of the first group
        zeros = jnp.zeros(sym.shape[:-1] + (pad,), sym.dtype)
        sym = jnp.concatenate([zeros, sym], axis=-1)
    grouped = sym.reshape(sym.shape[:-1] + (k, g))
    w = 4 ** jnp.arange(g - 1, -1, -1, dtype=jnp.int32)
    return jnp.sum(grouped * w, axis=-1)


def preprocess(sym_stack: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Unit P (paper III-A): merge each group of g consecutive symbols into a
    base-4 value and average over the N servers.

    sym_stack: (N, ..., M) -> A: (..., K), A_k in [0, 4^g - 1] step 1/N.
    """
    vals = group_symbols(sym_stack, bits, k)  # (N, ..., K)
    return jnp.mean(vals.astype(jnp.float32), axis=0)


def group_value(a: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Collapse K grouped unit-P inputs (..., K) back to the represented
    value: sum_k A_k * (4^g)^(K-1-k).  For K = 1 this is exact pass-through
    (weight 1.0); the photonic pipeline uses it to track the exact carried
    value of eq. 10."""
    g = preprocess_group_size(bits, k)
    w = (4.0 ** g) ** jnp.arange(k - 1, -1, -1)
    return jnp.sum(a.astype(jnp.float32) * w, axis=-1)


def symbol_value(sym: jnp.ndarray) -> jnp.ndarray:
    """Analog PAM4 symbol stream (..., M, MSB first) -> value, without the
    transceiver decision: sum_m y_m * 4^(M-1-m).  The float counterpart of
    ``pam4_decode`` for pre-readout (possibly noisy) ONN outputs."""
    m = sym.shape[-1]
    w = (4.0 ** jnp.arange(m - 1, -1, -1)).astype(jnp.float32)
    return jnp.sum(sym.astype(jnp.float32) * w, axis=-1)


def oracle_from_preprocessed(a: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Exact ONN transfer function: preprocessed inputs A (..., K) ->
    PAM4 symbols (..., M) of the quantized average."""
    u = jnp.round(group_value(a, bits, k)).astype(jnp.int32)
    return pam4_encode(u, bits)


def splitter(sym: jnp.ndarray, n: int) -> jnp.ndarray:
    """Unit T: broadcast the ONN output back to all N servers."""
    return jnp.broadcast_to(sym[None], (n,) + sym.shape)
