"""ONN resolution for the collective engine's photonic fidelities.

``OptincBackend.sync`` runs inside a shard_map trace; when
``SyncConfig.photonics.fidelity`` asks for the ``onn``/``mesh`` path it
needs the trained ``ONNModule`` as concrete arrays (closed over as jit
constants).  This module owns that resolution — keyed by
``(PhotonicsConfig, bits, n_servers)`` and cached process-wide so a
module is built/loaded/trained at most once per scenario, not once per
trace.

``warmup`` lets sessions resolve eagerly (outside any trace) so a slow
source ('train') pays its cost at build time, and a missing source
fails with guidance before the step loop starts.
"""
from __future__ import annotations

import dataclasses
import pathlib
import pickle

from .config import PhotonicsConfig
from .encoding import num_symbols
from .module import ONNModule
from .onn import ONNConfig

_CACHE: dict = {}

# quickstart --onn --scenario1 persists its trained params here (also the
# location benchmarks/table1.py reads)
RESULTS_PICKLES = ("results/scenario1_cayley_params.pkl",
                   "results/scenario1_params.pkl")

# src/repro/photonics/runtime.py -> the repo root, the same anchor
# benchmarks/common.py uses for results/ — so resolution does not depend
# on the launch directory (CWD is still tried as a fallback for
# installed-package layouts)
_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]


def _pickle_candidates():
    for name in RESULTS_PICKLES:
        yield _REPO_ROOT / name
        yield pathlib.Path(name)


def clamp_k(bits: int, k: int) -> int:
    """K cannot exceed the PAM4 symbol count M = ceil(bits/2)."""
    return max(1, min(k, num_symbols(bits)))


def default_structure(bits: int, k_inputs: int) -> tuple:
    """Default ONN structure for a bit width: the paper's scenario-1 shape
    (K, 64, 128, 256, 128, 64, M), collapsing to the exact-identity shape
    when the transfer function is a single symbol."""
    m = num_symbols(bits)
    k = clamp_k(bits, k_inputs)
    if m == 1 and k == 1:
        return (1, 4, 1)
    return (k, 64, 128, 256, 128, 64, m)


def onn_config(ph: PhotonicsConfig, bits: int, n_servers: int) -> ONNConfig:
    k = clamp_k(bits, ph.k_inputs)
    structure = ph.structure or default_structure(bits, ph.k_inputs)
    return ONNConfig(structure=tuple(structure),
                     approx_layers=tuple(ph.approx_layers),
                     bits=bits, n_servers=n_servers, k_inputs=k)


def _load_results(cfg: ONNConfig, adopt_structure: bool) -> ONNModule | None:
    """Load a pickle whose saved ONNConfig is usable for ``cfg``.

    With an explicit requested structure the saved config must match it
    EXACTLY (structure, approx_layers, bits, N, K): params trained
    without the approximation projection would silently mis-map onto the
    mesh, and an ONN trained for a different N sees inputs off its
    1/N-step training grid, so 100% accuracy no longer transfers.  With
    ``adopt_structure`` (PhotonicsConfig.structure == (), i.e. "use what
    is trained"), only (bits, N, K) must match and the saved structure /
    approx_layers are adopted wholesale."""
    def fp(c):
        key = (c.bits, c.n_servers, c.k_inputs)
        return key if adopt_structure else (
            key + (tuple(c.structure), tuple(c.approx_layers)))

    for p in _pickle_candidates():
        if not p.exists():
            continue
        with open(p, "rb") as f:
            blob = pickle.load(f)
        saved = blob.get("cfg")
        if saved is not None and fp(saved) == fp(cfg):
            return ONNModule.from_params(saved if adopt_structure else cfg,
                                         blob["params"])
    return None


def _build(ph: PhotonicsConfig, bits: int, n_servers: int) -> ONNModule:
    cfg = onn_config(ph, bits, n_servers)
    exact_ok = (num_symbols(bits) == 1 and cfg.k_inputs == 1
                and not ph.structure)
    if ph.params == "exact" or (ph.params == "auto" and exact_ok):
        return ONNModule.exact_identity(bits, n_servers)
    if ph.params in ("results", "auto"):
        module = _load_results(cfg, adopt_structure=not ph.structure)
        if module is not None:
            return module
        if ph.params == "results":
            raise ValueError(
                f"photonics params='results' but no matching pickle in "
                f"{RESULTS_PICKLES} for structure {cfg.structure} "
                f"(run `python examples/quickstart.py --onn --scenario1` "
                f"to produce one)")
    if ph.params == "train" or (ph.params == "auto" and ph.train_epochs > 0):
        if ph.train_epochs <= 0:
            raise ValueError("photonics params='train' needs train_epochs>0")
        return ONNModule.train(cfg, epochs=ph.train_epochs, seed=ph.seed)
    raise ValueError(
        f"cannot resolve an ONN for fidelity={ph.fidelity!r} at bits={bits}: "
        f"no trained params found.  Use --bits 2 (built-in exact identity "
        f"ONN), train scenario-1 params (`python examples/quickstart.py "
        f"--onn --scenario1`), or set PhotonicsConfig(params='train', "
        f"train_epochs=...)")


def _cache_key(ph: PhotonicsConfig, bits: int, n_servers: int):
    # the resolved module is executor- and noise-independent: mesh_backend
    # and the kernel tiling knob blk_b only select how the compiled
    # programs are APPLIED, and PhaseNoise perturbs them per-apply at
    # runtime — so runs comparing xla vs pallas, blk_b sweeps, or
    # noise-on vs noise-off in one process must share one
    # build/Givens-programming
    return (dataclasses.replace(ph, mesh_backend="xla", blk_b=0,
                                theta_drift_std=0.0, shot_noise_std=0.0),
            bits, n_servers)


def get_module(ph: PhotonicsConfig, bits: int, n_servers: int) -> ONNModule:
    """The cached ONNModule for one (photonics, bits, N) scenario."""
    key = _cache_key(ph, bits, n_servers)
    if key not in _CACHE:
        module = _build(ph, bits, n_servers)
        if ph.fidelity == "mesh":
            module.programs  # Givens-program the meshes once, eagerly
        _CACHE[key] = module
    return _CACHE[key]


def put_module(ph: PhotonicsConfig, bits: int, n_servers: int,
               module: ONNModule) -> None:
    """Pre-populate the cache (tests / custom-trained modules)."""
    _CACHE[_cache_key(ph, bits, n_servers)] = module


def warmup(sync_cfg, n_servers: int) -> ONNModule | None:
    """Resolve the ONN for a SyncConfig eagerly (no-op for behavioral)."""
    ph = getattr(sync_cfg, "photonics", None)
    if ph is None or ph.fidelity == "behavioral":
        return None
    return get_module(ph, sync_cfg.bits, n_servers)
