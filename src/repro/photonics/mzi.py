"""MZI hardware model: interleaving arrays, Givens decomposition, programming.

An M x M real orthogonal matrix is realized by M(M-1)/2 MZIs (paper Fig. 2,
the interleaving/Clements arrangement). Each MZI acting on waveguides (i, j)
implements a 2x2 rotation parameterized by its phase shifters; the real
restriction of the unitary group that the mesh generates is exactly the set
of Givens rotations, so programming the mesh == Givens decomposition.

The diagonal Sigma of an SVD (or the Sigma_a of the paper's approximation)
is realized by one column of M MZIs used as attenuators.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MZIProgram:
    """Phase program for one orthogonal matrix on an M-port mesh."""
    dim: int
    # list of (i, j, theta): rotation in the (i, j) plane
    rotations: list
    # output sign flips (absorbed into the diagonal column / output phases)
    signs: np.ndarray

    @property
    def num_mzis(self) -> int:
        return self.dim * (self.dim - 1) // 2


def givens_decompose(o: np.ndarray, tol: float = 1e-9) -> MZIProgram:
    """Decompose real orthogonal ``o`` into M(M-1)/2 Givens rotations.

    o = diag(signs) @ prod(R(i,j,theta))  (product applied right-to-left)
    """
    o = np.asarray(o, dtype=np.float64)
    m = o.shape[0]
    assert o.shape == (m, m)
    if not np.allclose(o @ o.T, np.eye(m), atol=1e-6):
        raise ValueError("matrix is not orthogonal")
    work = o.copy()
    rotations = []
    # zero out sub-diagonal entries column by column (QR with Givens);
    # G @ work only touches rows (row-1, row), so update just that pair —
    # O(m) per rotation instead of an m x m matmul (matters when
    # programming the 256-port meshes of the paper's larger scenarios)
    for col in range(m - 1):
        for row in range(m - 1, col, -1):
            a, b = work[row - 1, col], work[row, col]
            if abs(b) < tol:
                continue
            theta = np.arctan2(b, a)
            c, s = np.cos(theta), np.sin(theta)
            hi, lo = work[row - 1].copy(), work[row]
            work[row - 1] = c * hi + s * lo
            work[row] = -s * hi + c * lo
            rotations.append((row - 1, row, float(theta)))
    signs = np.sign(np.diag(work))
    signs[signs == 0] = 1.0
    if not np.allclose(np.diag(signs) @ work, np.eye(m), atol=1e-6):
        raise ValueError("Givens elimination failed to reach identity")
    # o = (prod G_k)^{-1} diag(signs) => o = G_1^T ... G_K^T diag(signs)
    return MZIProgram(dim=m, rotations=rotations, signs=signs)


def reconstruct(program: MZIProgram) -> np.ndarray:
    """Rebuild the orthogonal matrix from the MZI phase program."""
    m = program.dim
    # elimination gave: G_K ... G_1 @ o = diag(signs)
    #   =>  o = G_1^T ... G_K^T @ diag(signs)
    acc = np.diag(program.signs.astype(np.float64))
    for (i, j, theta) in reversed(program.rotations):
        c, s = np.cos(theta), np.sin(theta)
        g = np.eye(m)
        g[i, i] = c
        g[i, j] = s
        g[j, i] = -s
        g[j, j] = c
        acc = g.T @ acc
    return acc


def program_matrix_svd(w: np.ndarray):
    """Program an arbitrary real matrix W = U S V^T onto two meshes + one
    diagonal column (paper eq. 1). Returns (prog_u, sigma, prog_v)."""
    u, s, vt = np.linalg.svd(w)
    return givens_decompose(u), s, givens_decompose(vt.T)


def apply_programmed_svd(prog_u: MZIProgram, sigma: np.ndarray,
                         prog_v: MZIProgram, x: np.ndarray) -> np.ndarray:
    """Optical forward pass through the programmed SVD mesh: W x."""
    u = reconstruct(prog_u)
    v = reconstruct(prog_v)
    m, n = u.shape[0], v.shape[0]
    s = np.zeros((m, n))
    s[: len(sigma), : len(sigma)] = np.diag(sigma)
    return u @ (s @ (v.T @ x))
