"""Cascading OptINC topology (paper III-C, Fig. 5, eq. 8-10).

Two levels of OptINCs support N^2 servers. Naive cascading quantizes twice
(eq. 9) and drops the level-1 decimal parts; the paper's fix (eq. 10) carries
the decimal part d as one extra, higher-resolution PAM4 output symbol from
level 1 into level 2, making the cascade exact w.r.t. eq. 8.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .encoding import num_symbols


def expected(u: np.ndarray) -> np.ndarray:
    """Eq. (8): single-shot quantized average over all N^2 servers.
    u: (N, N, ...) integer gradients."""
    n2 = u.shape[0] * u.shape[1]
    return np.round(u.reshape(-1, *u.shape[2:]).sum(0) / n2).astype(np.int64)


def basic_cascade(u: np.ndarray) -> np.ndarray:
    """Eq. (9): two naive quantized averages (loses the decimal parts)."""
    n1 = u.shape[1]
    lvl1 = np.round(u.sum(1) / n1)
    n0 = u.shape[0]
    return np.round(lvl1.sum(0) / n0).astype(np.int64)


def carry_cascade(u: np.ndarray, n_extra_levels: int = 1) -> np.ndarray:
    """Eq. (10): level-1 OptINCs emit the averaged gradient at resolution
    1/N (integer part + decimal part d merged into the last PAM4 symbol);
    level 2 averages the exact values and quantizes once."""
    n1 = u.shape[1]
    lvl1_exact = u.sum(1) / n1          # integer + decimal part d, res 1/N
    n0 = u.shape[0]
    return np.round(lvl1_exact.sum(0) / n0).astype(np.int64)


def extra_symbols(n_servers: int) -> int:
    """How many extra PAM4 symbols are needed to carry the decimal part at
    resolution 1/N: ceil(log4(N))."""
    s = 0
    r = 1
    while r < n_servers:
        r *= 4
        s += 1
    return s


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """The scaled scenario of paper IV: scenario-1 OptINCs (B=8, N=4)
    cascaded 5x in two levels to support 16 servers. The ONN structure is
    widened by inserting one extra matrix after the first layer and one
    before the last (both with matrix approximation)."""
    bits: int = 8
    n_per_optinc: int = 4

    def expanded_structure(self, base: tuple) -> tuple:
        # insert 64x64 matrices after the first and before the last layer
        return (base[0], base[1], base[1]) + base[2:-2] + (base[-2], base[-2], base[-1])

    def expanded_approx_layers(self, base_structure: tuple) -> tuple:
        """Base scenario-1 approximates all layers; the two inserted 64x64
        matrices are approximated too (paper IV)."""
        n_weights = len(self.expanded_structure(base_structure)) - 1
        return tuple(range(1, n_weights + 1))


def hardware_overhead(base_structure: tuple, base_approx: tuple) -> float:
    """MZI overhead of the expanded cascade ONN vs the base ONN (paper: ~10.5%)."""
    from . import area as area_mod
    cc = CascadeConfig()
    exp_struct = list(cc.expanded_structure(tuple(base_structure)))
    exp_approx = set(cc.expanded_approx_layers(tuple(base_structure)))
    base = area_mod.area_mzis(list(base_structure), set(base_approx))
    exp = area_mod.area_mzis(exp_struct, exp_approx)
    return exp / base - 1.0
