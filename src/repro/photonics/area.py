"""MZI area-cost model (paper II-B, Table I/II area columns).

Full SVD mapping of an M x N matrix:  (M(M+1) + N(N-1)) / 2 MZIs.
Approximated s x s block (eq. 4):     s(s+1)/2 MZIs
                                      (s(s-1)/2 for U_a + s diagonal).
"""
from __future__ import annotations


def mzi_count_svd(m: int, n: int) -> int:
    return (m * (m + 1) + n * (n - 1)) // 2


def mzi_count_approx(m: int, n: int) -> int:
    s = min(m, n)
    assert m % s == 0 and n % s == 0
    nblocks = (m // s) * (n // s)
    return nblocks * (s * (s + 1) // 2)


def layer_dims(structure: list[int]) -> list[tuple[int, int]]:
    """[4, 64, 128, ..., 4] -> [(64,4), (128,64), ...] (out x in)."""
    return [(structure[i + 1], structure[i]) for i in range(len(structure) - 1)]


def area_mzis(structure: list[int], approx_layers: set[int] | None = None) -> int:
    """Total MZI count. ``approx_layers`` uses the paper's 1-based layer
    indices (layer i = weight between neurons i and i+1)."""
    approx_layers = approx_layers or set()
    total = 0
    for idx, (m, n) in enumerate(layer_dims(structure), start=1):
        if idx in approx_layers:
            total += mzi_count_approx(m, n)
        else:
            total += mzi_count_svd(m, n)
    return total


def area_ratio(structure: list[int], approx_layers: set[int]) -> float:
    """Area of the approximated ONN / area of the full-SVD ONN (Table I col 5)."""
    return area_mzis(structure, approx_layers) / area_mzis(structure, set())
