"""repro.photonics — the optical subsystem, in one place.

The paper's device story (PAM4 encoding -> preprocessing unit P ->
MZI-implementable ONN -> transceiver readout) used to be scattered
across ``repro.core``; this package is its single home, split by layer:

  encoding.py     PAM4 symbols, block quantization, the P unit (eq. 2-3)
  onn.py          the ONN f_theta + ONNConfig + Transceiver (paper IV)
  approx.py       Sigma_a U_a matrix approximation (eq. 4-6)
  mzi.py          Givens programming of MZI meshes — numpy ORACLE
  mesh.py         vectorized jittable mesh EMULATOR (lax.scan layers)
  area.py         MZI area-cost model (Tables I/II)
  training.py     hardware-aware two-stage training (III-B, eq. 7)
  dataset.py      ONN training grids (III-A/III-C)
  error_model.py  Table-II error injection
  cascade.py      two-level carry-cascade math (III-C, eq. 8-10)
  module.py       ONNModule: params + compiled mesh programs, per fidelity
  config.py       PhotonicsConfig: the runtime fidelity knob
  pipeline.py     SyncPipeline: Encode->Preprocess->MeshApply->Readout->
                  Decode stages + the PhaseNoise model — the composable
                  photonic reduction the collective backends run
  runtime.py      cached ONN resolution for the collective engine

``repro.core.{onn,mzi,approx,training,error_model,encoding,area,dataset,
cascade}`` re-export this surface for backwards compatibility.
"""
from . import (approx, area, cascade, dataset, encoding, error_model, mesh,
               mzi, onn, pipeline, training)
from .config import (FIDELITIES, MESH_BACKENDS, PhotonicsConfig,
                     resolve_interpret)
from .mesh import MZIMesh, compile_hardware
from .module import ONNModule
from .onn import ONNConfig, Transceiver
from .pipeline import PhaseNoise, SyncPipeline, level_pipeline
from .runtime import get_module, put_module, warmup

__all__ = [
    "PhotonicsConfig", "FIDELITIES", "MESH_BACKENDS", "resolve_interpret",
    "ONNConfig", "ONNModule", "MZIMesh", "Transceiver",
    "PhaseNoise", "SyncPipeline", "level_pipeline",
    "compile_hardware", "get_module", "put_module", "warmup",
    "approx", "area", "cascade", "dataset", "encoding", "error_model",
    "mesh", "mzi", "onn", "pipeline", "training",
]
