"""Bucketed gradient-sync engine (runs inside shard_map).

``sync_gradients`` is the single entry point the train step uses: it
flattens the gradient pytree into fused buckets (bucketizer.py), resolves
``SyncConfig.mode`` through the backend registry, and launches ONE
collective sequence per bucket — O(ceil(total_bytes / bucket_bytes))
launches per step instead of one per parameter leaf.

Error feedback (beyond-paper) is carried as a single 1-D f32 residual
vector aligned with the concatenated-leaf space: it is added to the
fused gradient stream before quantization and replaced by the backend's
per-bucket local quantization error, so residuals genuinely persist
across steps (the train step threads this vector as explicit state).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..photonics.config import PhotonicsConfig
from .bucketizer import (DEFAULT_BUCKET_BYTES, bucketize, flatten_concat,
                         make_layout, unbucketize)
from .registry import get_backend


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mode: str = "optinc"            # any registered backend name
    axes: tuple = ("data",)         # mesh axes to synchronize over
    bits: int = 8                    # OptINC gradient bit width B
    block: int = 2048                # quantization block size (0 = global)
    error_layers: tuple = ()         # Table II key, () = ideal ONN
    error_feedback: bool = False     # beyond-paper residual accumulation
    bucket_bytes: int = DEFAULT_BUCKET_BYTES  # fused-bucket wire payload
    # emulation fidelity of the optinc backend: behavioral | onn | mesh
    # (repro.photonics; 'onn'/'mesh' put the trained ONN / the MZI mesh
    # emulator itself inside the jit-compiled collective)
    photonics: PhotonicsConfig = PhotonicsConfig()


def residual_size(leaves) -> int:
    """Length of the error-feedback residual vector for a leaf list
    (arrays or ShapeDtypeStructs): the concatenated element count."""
    return sum(int(l.size) for l in leaves)


def sync_gradients(grads, cfg: SyncConfig, key: jax.Array | None = None,
                   residual: jnp.ndarray | None = None):
    """Synchronize (average) ``grads`` across cfg.axes.

    Returns ``(synced_grads, new_residual)``.  ``residual`` is a 1-D f32
    vector over the concatenated leaf space (see ``residual_size``); when
    ``cfg.error_feedback`` is set it is added back into the gradient
    stream before quantization and the returned vector holds this step's
    local quantization error (None for exact backends / feedback off).
    """
    backend = get_backend(cfg.mode)
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads, residual
    layout = make_layout(leaves, cfg.bucket_bytes)
    flat = flatten_concat(leaves)
    if cfg.error_feedback and residual is not None:
        flat = flat + residual.astype(jnp.float32)
    buckets = [flat[s:e] for s, e in layout.bounds]
    keys = (jax.random.split(key, len(buckets)) if key is not None
            else [None] * len(buckets))
    outs, errs = [], []
    for b, k in zip(buckets, keys):
        out, err = backend.sync(b, cfg, k)
        outs.append(out)
        errs.append(err)
    synced = jax.tree.unflatten(treedef, unbucketize(outs, layout))
    new_residual = None
    if cfg.error_feedback and all(e is not None for e in errs):
        new_residual = jnp.concatenate(errs) if errs else jnp.zeros(
            (0,), jnp.float32)
    return synced, new_residual
