"""Bucketed gradient-sync engine (runs inside shard_map).

``sync_gradients`` is the single entry point the train step uses: it
flattens the gradient pytree into fused buckets (bucketizer.py), resolves
``SyncConfig.mode`` through the backend registry, and launches ONE
collective sequence per bucket — O(ceil(total_bytes / bucket_bytes))
launches per step instead of one per parameter leaf.

Error feedback (beyond-paper) is carried as a single 1-D f32 residual
vector aligned with the concatenated-leaf space: it is added to the
fused gradient stream before quantization and replaced by the backend's
per-bucket local quantization error, so residuals genuinely persist
across steps (the train step threads this vector as explicit state).

``SyncConfig.overlap`` selects between two dispatch strategies:

* overlap off (default) — the historical barrier path: flatten-concat
  the FULL gradient pytree, then one lax.scan over the stacked full-size
  buckets (compile-once).  The concat makes every bucket's collective
  depend on every leaf, so the fabric sees its first symbol only after
  the whole backward finishes.  This path is kept byte-for-byte — its
  jaxpr is regression-gated against a frozen reference.
* overlap on — the streaming path (``_sync_streaming``): each bucket is
  assembled from ONLY the leaves it spans (``bucket_segments``) and its
  own residual slice, and buckets are dispatched in gradient-readiness
  order (``launch_order``: backward emits leaf gradients in reverse tree
  order, so the bucket covering the END of the concat space launches
  first, while earlier layers are still differentiating).  Synced leaves
  are likewise rebuilt from only their own buckets (``leaf_segments``) —
  no all-bucket join on the output side either.  Per bucket the math,
  the key (``split(key, n_buckets)[b]``), and the residual slice are
  IDENTICAL to the barrier path, so overlap changes launch ordering and
  dataflow dependencies, never numerics (bit-exactness is
  regression-gated).  The cost: the scan's compile-once body is given up
  for O(n_buckets) unrolled launches.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..photonics.config import PhotonicsConfig
from .bucketizer import (DEFAULT_BUCKET_BYTES, bucket_segments, bucketize,
                         flatten_concat, launch_order, leaf_segments,
                         make_layout, unbucketize)
from .registry import get_backend


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mode: str = "optinc"            # any registered backend name
    axes: tuple = ("data",)         # mesh axes to synchronize over
    bits: int = 8                    # OptINC gradient bit width B
    block: int = 2048                # quantization block size (0 = global)
    error_layers: tuple = ()         # Table II key, () = ideal ONN
    error_feedback: bool = False     # beyond-paper residual accumulation
    bucket_bytes: int = DEFAULT_BUCKET_BYTES  # fused-bucket wire payload
    # stream buckets in gradient-readiness order so collectives overlap
    # the remaining backward (module docstring; bit-exact vs overlap off)
    overlap: bool = False
    # checkpoint the residual vectors block-sparsely (only blocks with a
    # nonzero carry are stored — pack_residuals/unpack_residuals), cutting
    # checkpoint size for mostly-exact backends; runtime state stays dense
    sparse_residuals: bool = False
    # emulation fidelity of the optinc/cascade backends: behavioral | onn
    # | mesh (repro.photonics; 'onn'/'mesh' put the trained ONN / the MZI
    # mesh emulator itself inside the jit-compiled collective)
    photonics: PhotonicsConfig = PhotonicsConfig()


def residual_size(leaves) -> int:
    """Length of the error-feedback residual vector for a leaf list
    (arrays or ShapeDtypeStructs): the concatenated element count."""
    return sum(int(l.size) for l in leaves)


# ------------------- block-sparse residual checkpointing -------------------
#
# Error-feedback residuals are dense f32 vectors over the concatenated
# leaf space at RUNTIME (jit-friendly), but for mostly-exact backends
# (high bit widths, zero-gradient blocks, exact modes degraded from
# cascade) most blocks carry exactly zero quantization error.  With
# ``SyncConfig.sparse_residuals`` the checkpoint stores, per residual
# vector, only the blocks with a nonzero carry: {"idx", "val", "shape"}.
# ``shape`` = (size, block); the round trip is lossless by construction.

RESIDUAL_BLOCK = 4096  # f32 elements per stored block (16 KiB)


def pack_residuals(state: dict, block: int = RESIDUAL_BLOCK) -> dict:
    """Dense sync_state ({name: 1-D f32}) -> block-sparse host-side form."""
    packed = {}
    for name, vec in state.items():
        v = np.asarray(vec, np.float32).reshape(-1)
        n = v.size
        nb = -(-n // block) if n else 0
        full = np.zeros((nb * block,), np.float32)
        full[:n] = v
        blocks = full.reshape(nb, block)
        idx = np.flatnonzero(np.any(blocks != 0.0, axis=1)).astype(np.int32)
        packed[name] = {"idx": idx, "val": blocks[idx],
                        "shape": np.array([n, block], np.int64)}
    return packed


def unpack_residuals(packed: dict) -> dict:
    """Block-sparse checkpoint form -> dense numpy sync_state."""
    state = {}
    for name, entry in packed.items():
        n, block = (int(x) for x in np.asarray(entry["shape"]))
        nb = -(-n // block) if n else 0
        full = np.zeros((nb * block,), np.float32)
        idx = np.asarray(entry["idx"], np.int64)
        if idx.size:
            full.reshape(nb, block)[idx] = np.asarray(entry["val"],
                                                      np.float32)
        state[name] = full[:n]
    return state


def is_packed_residuals(tree) -> bool:
    """True when a checkpointed sync subtree is in the block-sparse form
    (each entry a {"idx", "val", "shape"} dict) rather than dense vectors
    — resume handles either form regardless of the current flag."""
    return bool(tree) and all(
        isinstance(v, dict) and set(v) == {"idx", "val", "shape"}
        for v in tree.values())


def _sync_streaming(leaves, treedef, layout, backend, cfg: SyncConfig,
                    key, residual, readiness):
    """The overlap-on dispatch: per-bucket dataflow, readiness-ordered.

    Bucket b's input is concatenated from the slices of ONLY the leaves
    it spans (plus its own residual slice), so its collective launch
    depends on nothing emitted after those gradients; synced leaves are
    rebuilt from only the buckets covering them.  Dispatch follows
    ``launch_order`` — with the default reverse-emission readiness the
    LAST bucket (deepest layers, first gradients out of backward) goes
    on the wire first.  Every per-bucket quantity (key, residual slice,
    backend math) matches the barrier path bit-for-bit; only the trace
    order and the dependency structure differ.
    """
    segs = bucket_segments(layout)
    order = launch_order(layout, readiness)
    nb = layout.n_buckets
    keys = (jax.random.split(key, nb) if key is not None else [None] * nb)
    flats = {}

    def leaf_flat(i):
        if i not in flats:
            flats[i] = jnp.reshape(leaves[i], (-1,)).astype(jnp.float32)
        return flats[i]

    outs, errs = [None] * nb, [None] * nb
    for b in order:
        parts = [leaf_flat(i)[a:t] if (a, t) != (0, layout.sizes[i])
                 else leaf_flat(i) for i, a, t in segs[b]]
        bucket = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        if cfg.error_feedback and residual is not None:
            s, e = layout.bounds[b]
            bucket = bucket + residual[s:e].astype(jnp.float32)
        outs[b], errs[b] = backend.sync(bucket, cfg, keys[b])
    synced = []
    for i, (shape, dtype, pieces) in enumerate(
            zip(layout.shapes, layout.dtypes, leaf_segments(layout))):
        if not pieces:
            flat = jnp.zeros((0,), jnp.float32)
        elif len(pieces) == 1:
            b, s, e = pieces[0]
            flat = outs[b][s:e]
        else:
            flat = jnp.concatenate([outs[b][s:e] for b, s, e in pieces])
        synced.append(flat.reshape(shape).astype(dtype))
    new_residual = None
    if cfg.error_feedback and all(e is not None for e in errs):
        new_residual = (jnp.concatenate(errs) if errs
                        else jnp.zeros((0,), jnp.float32))
    return jax.tree.unflatten(treedef, synced), new_residual


def sync_gradients(grads, cfg: SyncConfig, key: jax.Array | None = None,
                   residual: jnp.ndarray | None = None, readiness=None):
    """Synchronize (average) ``grads`` across cfg.axes.

    Returns ``(synced_grads, new_residual)``.  ``residual`` is a 1-D f32
    vector over the concatenated leaf space (see ``residual_size``); when
    ``cfg.error_feedback`` is set it is added back into the gradient
    stream before quantization and the returned vector holds this step's
    local quantization error (None for exact backends / feedback off).
    ``readiness`` (per-leaf emission ranks, overlap mode only) overrides
    the default reverse-tree-order backward-emission model.
    """
    backend = get_backend(cfg.mode)
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads, residual
    layout = make_layout(leaves, cfg.bucket_bytes)
    if cfg.overlap:
        return _sync_streaming(leaves, treedef, layout, backend, cfg, key,
                               residual, readiness)
    flat = flatten_concat(leaves)
    if cfg.error_feedback and residual is not None:
        flat = flat + residual.astype(jnp.float32)
    buckets = [flat[s:e] for s, e in layout.bounds]
    keys = (jax.random.split(key, len(buckets)) if key is not None
            else [None] * len(buckets))
    # All buckets except a ragged tail share one shape, so their sync is
    # ONE lax.scan over the stacked bucket axis: the backend body (for
    # the photonic fidelities, the whole emulated pipeline) is traced and
    # compiled ONCE instead of once per bucket — a 43M-param model at
    # 4 MiB buckets is 41 buckets, and the Python-unrolled form made the
    # mesh fidelity's XLA compile, not its runtime, the step bottleneck.
    # Per-bucket math and keys are identical, so the scan is bit-exact
    # against the unrolled loop (regression-tested).
    n_full = sum(1 for s, e in layout.bounds
                 if e - s == layout.bucket_elems)
    outs, errs = [], []
    if n_full >= 2:
        xs = jnp.stack(buckets[:n_full])
        if key is not None:
            _, (out_s, err_s) = jax.lax.scan(
                lambda c, bk: (c, backend.sync(bk[0], cfg, bk[1])),
                None, (xs, keys[:n_full]))
        else:
            _, (out_s, err_s) = jax.lax.scan(
                lambda c, b: (c, backend.sync(b, cfg, None)), None, xs)
        outs = list(out_s)
        errs = list(err_s) if err_s is not None else [None] * n_full
        buckets, keys = buckets[n_full:], keys[n_full:]
    for b, k in zip(buckets, keys):
        out, err = backend.sync(b, cfg, k)
        outs.append(out)
        errs.append(err)
    synced = jax.tree.unflatten(treedef, unbucketize(outs, layout))
    new_residual = None
    if cfg.error_feedback and all(e is not None for e in errs):
        new_residual = jnp.concatenate(errs) if errs else jnp.zeros(
            (0,), jnp.float32)
    return synced, new_residual
