"""Pluggable bucket-fused collective engine for gradient synchronization.

Layout:
  registry.py    — register_backend / get_backend
  backends.py    — psum | ring | optinc | cascade implementations with
                   per-backend wire-byte accounting (bytes_on_wire)
  bucketizer.py  — pytree <-> fixed-size fused f32 buckets
  engine.py      — SyncConfig + sync_gradients (the train-step entry)

``repro.core.collective`` re-exports this surface for backwards
compatibility with the pre-refactor import path.
"""
from .. import compat  # noqa: F401  (installs jax API shims first)

from .backends import (CascadeBackend, OptincBackend, PsumBackend,
                       RingBackend, _ring_allreduce_flat)
from .bucketizer import (DEFAULT_BUCKET_BYTES, BucketLayout, bucketize,
                         expected_buckets, make_layout, tree_bucketize,
                         tree_unbucketize, unbucketize)
from .engine import (SyncConfig, is_packed_residuals, pack_residuals,
                     residual_size, sync_gradients, unpack_residuals)
from .registry import available_backends, get_backend, register_backend

__all__ = [
    "SyncConfig", "sync_gradients", "residual_size",
    "pack_residuals", "unpack_residuals", "is_packed_residuals",
    "register_backend", "get_backend", "available_backends",
    "PsumBackend", "RingBackend", "OptincBackend", "CascadeBackend",
    "BucketLayout", "make_layout", "bucketize", "unbucketize",
    "tree_bucketize", "tree_unbucketize", "expected_buckets",
    "DEFAULT_BUCKET_BYTES",
]
