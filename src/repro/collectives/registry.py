"""Pluggable collective-backend registry.

A backend owns ONE bucket's synchronization inside shard_map plus the
analytic wire models the benchmarks and the perf gate consume
(EXPERIMENTS.md §Fig6, §Overlap):

  sync(flat, cfg, key) -> (synced, local_err | None)
      ``flat`` is a 1-D float32 fused bucket, identical math on every
      peer of ``cfg.axes``.  ``local_err`` is this device's quantization
      error (for error feedback) or None for exact backends.

  bytes_on_wire(nbytes, n, bits) -> float
      Per-device send-direction wire bytes to synchronize ``nbytes`` of
      raw bf16 gradient across ``n`` peers at gradient width ``bits``.

  time_on_wire(nbytes, n, bits, overlap=False, bucket_bytes=...) -> float
      Per-device seconds the same sync keeps the wire and the
      reconfigurable optical fabric busy: line-rate transfer plus
      per-bucket circuit-reconfiguration latency, pipelined when
      ``overlap`` (the streaming engine) is on.  ``overlap=True`` must
      never exceed ``overlap=False`` — the perf gate holds backends to
      that ratio.

Register custom engines with ``register_backend`` (e.g. experiment
forks, hardware simulators); the runtime resolves ``SyncConfig.mode``
through ``get_backend`` so a registered name is immediately usable as
``--sync <name>``.
"""
from __future__ import annotations

_REGISTRY: dict = {}


def register_backend(name: str, backend, overwrite: bool = False):
    """Register ``backend`` (an object with sync/bytes_on_wire/
    time_on_wire) under ``name``. Returns the backend so it can be used
    as a decorator-ish one-liner at definition sites."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"collective backend {name!r} already registered")
    for attr in ("sync", "bytes_on_wire", "time_on_wire"):
        if not callable(getattr(backend, attr, None)):
            raise TypeError(f"backend {name!r} lacks a callable {attr}()")
    _REGISTRY[name] = backend
    return backend


def get_backend(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown sync mode {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))
