"""Gradient-bucket fusion.

Flattens a gradient pytree into fixed-size float32 buckets so that block
quantization, the integer reduce, and the all-gather each launch once per
bucket instead of once per parameter leaf — O(buckets) collectives per
step for a model with hundreds of leaves.  Leaves are concatenated in
tree order and sliced at fixed ``bucket_bytes`` boundaries, so a bucket
may span leaf boundaries (quantization block scales are shared across
them, the paper's global block quantization applied to the fused stream)
and the final bucket may be short.

The layout is static (shapes/dtypes only), so it can be computed from
ShapeDtypeStructs at trace time and reused across steps.

For the streaming (backward/comm-overlap) engine the same layout also
answers two structural questions without touching any array data:
``bucket_segments`` / ``leaf_segments`` map each bucket to the leaf
slices it fuses (and back), so a bucket's collective can be built from
ONLY the leaves it spans — the dataflow dependency that lets the
compiler launch bucket k's sync while the gradients of the leaves in
bucket k-1 are still being differentiated — and ``launch_order`` turns
per-leaf readiness ranks (backward emits leaf gradients in reverse tree
order) into the bucket dispatch schedule.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

DEFAULT_BUCKET_BYTES = 4 * 2 ** 20   # 4 MiB of f32 wire payload per bucket


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static description of how a leaf list maps onto fused buckets."""
    shapes: tuple           # per-leaf shapes
    dtypes: tuple           # per-leaf dtypes
    sizes: tuple            # per-leaf element counts
    total: int              # sum(sizes)
    bucket_elems: int       # elements per full bucket
    bounds: tuple           # per-bucket (start, end) in concat space

    @property
    def n_buckets(self) -> int:
        return len(self.bounds)


def make_layout(leaves, bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> BucketLayout:
    """Layout for ``leaves`` (arrays or ShapeDtypeStructs)."""
    if bucket_bytes <= 0:
        raise ValueError(
            f"bucket_bytes must be positive, got {bucket_bytes} "
            "(a 0 --bucket-mb would mean one collective per element)")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(l.size) for l in leaves)
    total = sum(sizes)
    bucket_elems = max(int(bucket_bytes) // 4, 1)
    bounds = tuple((s, min(s + bucket_elems, total))
                   for s in range(0, total, bucket_elems))
    if not bounds and total == 0:
        bounds = ()
    return BucketLayout(shapes=shapes, dtypes=dtypes, sizes=sizes,
                        total=total, bucket_elems=bucket_elems, bounds=bounds)


def bucket_segments(layout: BucketLayout) -> tuple:
    """Per-bucket leaf coverage: a tuple (one entry per bucket) of
    ``(leaf_idx, start, stop)`` triples, where ``[start, stop)`` is the
    LEAF-LOCAL flat slice that bucket fuses.  Together the triples of
    bucket b tile exactly ``layout.bounds[b]`` of the concat space; a
    zero-size leaf appears in no bucket.  Static — trace-time only."""
    segs, offsets, off = [], [], 0
    for sz in layout.sizes:
        offsets.append(off)
        off += sz
    for s, e in layout.bounds:
        cur = []
        for i, (lo, sz) in enumerate(zip(offsets, layout.sizes)):
            a, b = max(s, lo), min(e, lo + sz)
            if a < b:
                cur.append((i, a - lo, b - lo))
        segs.append(tuple(cur))
    return tuple(segs)


def leaf_segments(layout: BucketLayout) -> tuple:
    """The transpose of ``bucket_segments``: per-leaf tuple of
    ``(bucket_idx, start, stop)`` triples in bucket order, where
    ``[start, stop)`` is the BUCKET-LOCAL slice holding that part of the
    leaf.  A zero-size leaf gets an empty tuple."""
    per_leaf = [[] for _ in layout.sizes]
    for b, seg in enumerate(bucket_segments(layout)):
        s = layout.bounds[b][0]
        off = 0
        for i, a, t in seg:
            per_leaf[i].append((b, off, off + (t - a)))
            off += t - a
        assert s + off == layout.bounds[b][1]
    return tuple(tuple(p) for p in per_leaf)


def launch_order(layout: BucketLayout, readiness=None) -> tuple:
    """Bucket dispatch schedule for the streaming engine.

    ``readiness`` is a per-leaf emission rank — the (relative) time at
    which that leaf's gradient becomes available during backward; lower
    = earlier.  Default: backward differentiates the network back to
    front, so leaf gradients are emitted in REVERSE tree order
    (``readiness[i] = n_leaves - 1 - i``).  A bucket is ready when its
    LATEST leaf is (max over its segments); buckets are dispatched in
    ready order, ties broken by DESCENDING bucket index (buckets
    unblocked by the same leaf stream end-of-concat-space first, matching
    the reverse-emission narrative), so under the default the schedule is
    simply the reversed bucket index order.
    """
    if readiness is None:
        n = len(layout.sizes)
        readiness = tuple(n - 1 - i for i in range(n))
    if len(readiness) != len(layout.sizes):
        raise ValueError(
            f"readiness must rank every leaf: got {len(readiness)} ranks "
            f"for {len(layout.sizes)} leaves")
    segs = bucket_segments(layout)
    ready = [max((readiness[i] for i, _, _ in seg), default=0)
             for seg in segs]
    return tuple(sorted(range(len(segs)), key=lambda b: (ready[b], -b)))


def flatten_concat(leaves) -> jnp.ndarray:
    """Concatenate leaves (any shapes/dtypes) into one f32 vector."""
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate(
        [jnp.reshape(l, (-1,)).astype(jnp.float32) for l in leaves])


def bucketize(leaves, layout: BucketLayout) -> list:
    """Leaves -> list of 1-D f32 buckets (last one may be short)."""
    flat = flatten_concat(leaves)
    return [flat[s:e] for s, e in layout.bounds]


def unbucketize(buckets, layout: BucketLayout) -> list:
    """Buckets -> leaves with the layout's original shapes/dtypes.

    Exact round-trip for float32 leaves; lower-precision leaves (bf16,
    f16) round-trip exactly too because f32 holds them losslessly.
    """
    if not buckets:
        flat = jnp.zeros((0,), jnp.float32)
    else:
        flat = jnp.concatenate(buckets)
    out, off = [], 0
    for shape, dtype, size in zip(layout.shapes, layout.dtypes, layout.sizes):
        out.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return out


def expected_buckets(total_grad_bytes: int,
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> int:
    """ceil(total_grad_bytes / bucket_bytes): the collective-launch budget
    the engine must respect (asserted by tests against the jaxpr).

    Computed in f32 elements with the same floored per-bucket element
    count as ``make_layout``, so the budget matches the actual bucket
    count even when bucket_bytes is not a multiple of 4.
    """
    bucket_elems = max(int(bucket_bytes) // 4, 1)
    total_elems = -(-int(total_grad_bytes) // 4)
    return -(-total_elems // bucket_elems)


def tree_bucketize(tree, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Convenience: pytree -> (buckets, (treedef, layout))."""
    leaves, treedef = jax.tree.flatten(tree)
    layout = make_layout(leaves, bucket_bytes)
    return bucketize(leaves, layout), (treedef, layout)


def tree_unbucketize(buckets, aux):
    treedef, layout = aux
    return jax.tree.unflatten(treedef, unbucketize(buckets, layout))
