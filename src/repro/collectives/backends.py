"""Collective backends: psum | ring | optinc | cascade.

Each backend synchronizes ONE fused f32 bucket inside shard_map (see
bucketizer.py) and models its own wire bytes (``bytes_on_wire``,
EXPERIMENTS.md §Fig6) and wire TIME (``time_on_wire``, EXPERIMENTS.md
§Overlap: transfer at the transceiver line rate plus the per-bucket
circuit-reconfiguration latencies, with/without the streaming engine's
reconfiguration/transfer pipelining).  ``cascade`` is the paper's III-C two-level
carry-cascade (eq. 8-10) made a first-class runtime mode: level-1 OptINCs
reduce over the innermost sync axis and emit the average at resolution
1/N1 — carried losslessly as the integer partial sum, the ICI analogue of
the ``extra_symbols`` higher-precision PAM4 code — and level 2 reduces
across the remaining axes and quantizes ONCE (eq. 10), so the result is
bit-exact against photonics.cascade.carry_cascade / the one-shot eq. 8
average.  The optinc and cascade photonic fidelities are both expressed
through ``photonics.pipeline`` stage chains (one level for optinc, two
carry-linked levels for cascade).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..photonics import error_model
from ..photonics import pipeline as ph_pipeline
from ..photonics import runtime as ph_runtime
from ..photonics.cascade import extra_symbols
from ..photonics.encoding import QuantSpec, compute_scale
from .bucketizer import DEFAULT_BUCKET_BYTES, expected_buckets
from .registry import register_backend

_F32_TINY = 1.1754944e-38  # jnp.finfo(jnp.float32).tiny

# ------------------- time-on-wire model (EXPERIMENTS.md §Overlap) ----------
#
# ``time_on_wire(nbytes, n, bits, overlap)`` is the analytic sibling of
# ``bytes_on_wire``: the per-device seconds the full gradient sync keeps
# the wire (and, for the optical backends, the reconfigurable fabric)
# busy.  Gradients move as ceil(2*nbytes / bucket_bytes) fused f32
# buckets (nbytes is raw bf16 gradient bytes, so elems = nbytes/2 and
# the fused f32 stream is 2*nbytes); each bucket of the optical backends
# needs its MZI mesh(es) programmed for the reduction circuit before
# symbols flow.  ``overlap=False`` models today's barrier engine —
# reconfigure, transfer, reconfigure, transfer, strictly serial.
# ``overlap=True`` models the streaming engine: the fabric reprograms
# for bucket k+1 while bucket k's symbols are still in flight, and the
# cascade's level-0 pod reduction of bucket k+1 pipelines against the
# level-1 carry merge of bucket k, so after the pipeline fills only the
# bottleneck stage is exposed per bucket.

WIRE_BYTES_PER_S = 100e9     # one 800 Gb/s full-duplex optical transceiver
MESH_RECONFIG_S = 20e-6      # programming one MZI mesh circuit (thermal
                             # phase-shifter settle, SWOT-style reconfig)
HOP_LATENCY_S = 1e-6         # one electrical ppermute round (ring baseline)


def _n_buckets(nbytes: float, bucket_bytes: int) -> int:
    return max(expected_buckets(int(max(nbytes, 1) * 2), bucket_bytes), 1)


def _axis_size(axes) -> int:
    n = 1
    for ax in axes:
        n *= lax.axis_size(ax)
    return n


def _shared_scale(flat: jnp.ndarray, cfg) -> jnp.ndarray:
    """Per-block max-abs scale shared across all peers of cfg.axes (the
    paper's global block quantization, <0.4% sync cost)."""
    spec = QuantSpec(bits=cfg.bits, block=cfg.block)
    scale = compute_scale(flat, spec)
    for ax in cfg.axes:
        scale = lax.pmax(scale, ax)
    return scale


def _encode(flat: jnp.ndarray, scale: jnp.ndarray, cfg):
    """f32 bucket -> offset-binary B-bit codes, zero-block safe.

    An all-zero block (on every peer) leaves ``scale`` at the f32-tiny
    floor; dividing denormal-adjacent values by it can overflow to inf
    before the clip.  Blocks with scale at the floor are short-circuited
    to the zero code instead (regression-tested).
    """
    spec = QuantSpec(bits=cfg.bits, block=cfg.block)
    zero_block = scale <= _F32_TINY
    safe = jnp.where(zero_block, 1.0, scale)
    block = max(cfg.block, 1) if cfg.block > 0 else flat.size
    pad = (-flat.size) % max(block, 1)
    blocks = jnp.pad(flat, (0, pad)).reshape(scale.shape[0], -1)
    q = jnp.round(blocks / safe[:, None] * spec.levels)
    q = jnp.clip(q, -spec.levels, spec.levels).astype(jnp.int32)
    q = jnp.where(zero_block[:, None], 0, q)
    return q + spec.levels, q, safe, spec  # offset-binary u, signed q


def _decode(q_signed: jnp.ndarray, safe_scale: jnp.ndarray, spec,
            size: int) -> jnp.ndarray:
    deq = q_signed.astype(jnp.float32) * (safe_scale[:, None] / spec.levels)
    return deq.reshape(-1)[:size]


class PsumBackend:
    """XLA-native exact all-reduce mean (reference)."""
    name = "psum"

    def sync(self, flat, cfg, key):
        axes = cfg.axes[0] if len(cfg.axes) == 1 else cfg.axes
        return lax.pmean(flat, axes), None

    def bytes_on_wire(self, nbytes: float, n: int, bits: int) -> float:
        # ring-equivalent all-reduce: RS + AG, (N-1)/N of the payload each
        return 2.0 * (n - 1) / max(n, 1) * nbytes

    def time_on_wire(self, nbytes: float, n: int, bits: int,
                     overlap: bool = False,
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> float:
        # electrical all-reduce: no circuit to reconfigure, the wire stays
        # saturated either way — streaming changes WHEN bytes move, not
        # how many seconds they occupy the wire.  2(N-1) serial rounds
        # each pay one hop latency.
        return (self.bytes_on_wire(nbytes, n, bits) / WIRE_BYTES_PER_S
                + 2.0 * (n - 1) * HOP_LATENCY_S)


def _ring_allreduce_flat(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Manual ring all-reduce of one bucket over one mesh axis:
    reduce-scatter then all-gather, each via (N-1) ppermute rounds
    (paper Fig. 1)."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    pad = (-x.shape[0]) % n
    chunks = jnp.pad(x, (0, pad)).reshape(n, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    # Rounds are Python-unrolled so every ppermute appears in the HLO
    # (static collective accounting sees all 2(N-1) rounds) and XLA can
    # overlap consecutive rounds.
    for r in range(n - 1):
        sent = lax.ppermute(chunks[(idx - r) % n], axis, fwd)
        chunks = chunks.at[(idx - r - 1) % n].add(sent)
    for r in range(n - 1):
        sent = lax.ppermute(chunks[(idx + 1 - r) % n], axis, fwd)
        chunks = chunks.at[(idx - r) % n].set(sent)
    return chunks.reshape(-1)[: x.shape[0]]


class RingBackend:
    """Faithful ring all-reduce (the paper's baseline, 2(N-1)/N blow-up)."""
    name = "ring"

    def sync(self, flat, cfg, key):
        out = flat
        for ax in cfg.axes:
            out = _ring_allreduce_flat(out, ax)
        return out / _axis_size(cfg.axes), None

    def bytes_on_wire(self, nbytes: float, n: int, bits: int) -> float:
        return 2.0 * (n - 1) / max(n, 1) * nbytes

    time_on_wire = PsumBackend.time_on_wire  # same electrical wire model


def _quantized_sync(flat, cfg, key, scatter_plan):
    """Shared quantize -> integer reduce -> Q(mean) -> dequantize path.

    ``scatter_plan`` is the ordered (axis, int_dtype) reduce-scatter
    schedule; each stage runs in a dtype wide enough for its partial sum.
    The all-gather unwinds the plan in reverse.  Returns
    (synced, local_quantization_error) — the error is what this device's
    transceiver lost encoding its own gradient (error feedback).
    """
    n = _axis_size(cfg.axes)
    scale = _shared_scale(flat, cfg)
    u, q, safe, spec = _encode(flat, scale, cfg)
    flat_u = u.reshape(-1)
    parts = jnp.pad(flat_u, (0, (-flat_u.size) % n))
    for ax, dt in scatter_plan:
        parts = lax.psum_scatter(parts.astype(dt), ax,
                                 scatter_dimension=0, tiled=True)
    # single quantization of the reduced output (eq. 3 / eq. 10)
    u_avg = jnp.round(parts.astype(jnp.float32) / n).astype(jnp.int32)
    if cfg.error_layers and key is not None:
        spec_err = error_model.TABLE_II[tuple(cfg.error_layers)]
        u_avg = error_model.inject(key, u_avg, spec_err, cfg.bits)
    ag_dt = jnp.uint8 if cfg.bits <= 8 else jnp.uint16
    coded = u_avg.astype(ag_dt)
    for ax, _ in reversed(scatter_plan):
        coded = lax.all_gather(coded, ax, axis=0, tiled=True)
    u_avg = coded[: flat_u.size].astype(jnp.int32).reshape(u.shape)
    out = _decode(u_avg - spec.levels, safe, spec, flat.size)
    local = _decode(q, safe, spec, flat.size)
    return out, flat - local


def _noise_key(cfg, key, noise):
    """The level key seeding PhaseNoise, folded OFF the per-bucket sync
    key so Table-II error injection keeps drawing from the raw key
    (zero-noise runs trace bit-identical jaxprs to the pre-noise paths).
    A noisy run without a step key would silently train noise-free, so
    that combination is rejected at trace time."""
    if noise is None:
        return None
    if key is None:
        raise ValueError(
            "PhotonicsConfig noise (theta_drift_std/shot_noise_std > 0) "
            "needs a per-step sync key; pass key= to sync_gradients")
    return jax.random.fold_in(key, 1)


def _finish_photonic(u_avg, u, q, safe, spec, flat, cfg, key):
    """Shared epilogue of both photonic paths: Table-II error injection
    on the averaged codes, dequantize, and the local quantization error
    for error feedback."""
    if cfg.error_layers and key is not None:
        spec_err = error_model.TABLE_II[tuple(cfg.error_layers)]
        u_avg = error_model.inject(key, u_avg, spec_err, cfg.bits)
    out = _decode(u_avg.reshape(u.shape) - spec.levels, safe, spec,
                  flat.size)
    local = _decode(q, safe, spec, flat.size)
    return out, flat - local


def _photonic_sync(flat, cfg, key):
    """The hardware-in-the-loop OptINC path (fidelity = 'onn' | 'mesh').

    Instead of computing Q(mean) directly in the integer domain, the
    B-bit codes run ONE ``photonics.pipeline`` level over ``cfg.axes``:
    PAM4-encode + unit-P grouping (Encode), the fabric's exact integer
    average (Preprocess), the in-network ONN — trained dense forward
    ('onn') or the phase-programmed MZI mesh emulator ('mesh'), with the
    PhaseNoise model when configured (MeshApply) — then the transceiver
    decision and symbol decode (Readout/Decode).  The whole path is
    ordinary traced jax, so it jit-compiles inside ``sync_gradients``.
    """
    n = _axis_size(cfg.axes)
    ph = cfg.photonics
    module = ph_runtime.get_module(ph, cfg.bits, n)
    scale = _shared_scale(flat, cfg)
    u, q, safe, spec = _encode(flat, scale, cfg)
    noise = ph_pipeline.PhaseNoise.from_config(ph)
    pipe = ph_pipeline.level_pipeline(
        module, cfg.bits, cfg.axes, fidelity=ph.fidelity,
        mesh_backend=ph.mesh_backend, noise=noise, blk_b=ph.blk_b)
    u_avg = pipe.run(u.reshape(-1), key=_noise_key(cfg, key, noise)).data
    return _finish_photonic(u_avg, u, q, safe, spec, flat, cfg, key)


def _photonic_cascade_sync(flat, cfg, key):
    """Two-level carry-cascade THROUGH the emulated optical fabric.

    Two chained ``photonics.pipeline`` levels (paper III-C / eq. 10):
    level 0 reduces within the pod (the innermost sync axis) and emits
    the eq.-10 decimal part d off its analog readout as the pipeline
    carry; level 1 reduces across the remaining axes with d merged into
    its least-significant unit-P group and quantizes ONCE.  On a
    100%-accuracy ONN the result is bit-exact against the behavioral
    cascade (== the one-shot eq. 8 average); at lower ONN accuracy or
    with PhaseNoise on, both levels' hardware error propagates
    physically.  The level-0 ONN is resolved for N1 servers, the level-1
    ONN for all N (its carried inputs sit on the full 1/N grid).
    """
    from ..photonics.encoding import num_symbols
    if num_symbols(cfg.bits) != 1:
        # the emulated carry rides the least-significant unit-P group,
        # which only stays on the ONN's training grid for the
        # single-symbol transfer function; wider widths need
        # cascade-trained ONNs with a dedicated extra input (ROADMAP)
        raise ValueError(
            f"the photonic cascade (fidelity={cfg.photonics.fidelity!r}) "
            f"supports bits <= 2 (one PAM4 symbol per value, where the "
            f"eq.-10 carry is exactly representable on the unit-P grid); "
            f"got bits={cfg.bits}.  Use fidelity='behavioral' for wider "
            f"bit widths")
    lvl1_ax = cfg.axes[-1]
    lvl2_axes = cfg.axes[:-1]
    n1 = lax.axis_size(lvl1_ax)
    n = _axis_size(cfg.axes)
    ph = cfg.photonics
    mod0 = ph_runtime.get_module(ph, cfg.bits, n1)
    mod1 = ph_runtime.get_module(ph, cfg.bits, n)
    scale = _shared_scale(flat, cfg)
    u, q, safe, spec = _encode(flat, scale, cfg)
    noise = ph_pipeline.PhaseNoise.from_config(ph)
    nk = _noise_key(cfg, key, noise)
    nk0 = nk1 = None
    if nk is not None:
        nk0, nk1 = jax.random.split(nk)
    p0 = ph_pipeline.level_pipeline(
        mod0, cfg.bits, (lvl1_ax,), fidelity=ph.fidelity,
        mesh_backend=ph.mesh_backend, noise=noise, emit_carry=True,
        blk_b=ph.blk_b)
    p1 = ph_pipeline.level_pipeline(
        mod1, cfg.bits, lvl2_axes, fidelity=ph.fidelity,
        mesh_backend=ph.mesh_backend, noise=noise, blk_b=ph.blk_b)
    lvl0 = p0.run(u.reshape(-1), key=nk0)
    u_avg = p1.run(lvl0.data, key=nk1, frac=lvl0.frac).data
    return _finish_photonic(u_avg, u, q, safe, spec, flat, cfg, key)


class OptincBackend:
    """Quantize -> integer in-network sum -> Q(mean) -> dequantize.

    ``cfg.photonics.fidelity`` selects the emulation depth: 'behavioral'
    keeps the TPU ICI analogue of the optical sum at symbol width
    (reduce-scatter the B-bit codes in the narrowest integer type holding
    the N-way sum, apply the ONN transfer function Q(mean) on the
    scattered shard (eq. 3), all-gather the B-bit result); 'onn' / 'mesh'
    run the gathered symbol streams through the in-network ONN itself
    (``_photonic_sync``).
    """
    name = "optinc"

    def sync(self, flat, cfg, key):
        ph = getattr(cfg, "photonics", None)
        if ph is not None and ph.fidelity != "behavioral":
            return _photonic_sync(flat, cfg, key)
        n = _axis_size(cfg.axes)
        max_sum = (2 ** cfg.bits - 2) * n
        rs_dt = jnp.int16 if max_sum < 2 ** 15 else jnp.int32
        plan = [(ax, rs_dt) for ax in cfg.axes]
        return _quantized_sync(flat, cfg, key, plan)

    def bytes_on_wire(self, nbytes: float, n: int, bits: int) -> float:
        # one send of the B-bit codes into the optical fabric per server
        # (receive is symmetric; send-direction accounting)
        return (nbytes / 2.0) * bits / 8.0

    def time_on_wire(self, nbytes: float, n: int, bits: int,
                     overlap: bool = False,
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> float:
        # one reduction circuit per bucket: program the mesh, stream the
        # B-bit codes through at line rate.  Streaming hides every
        # reconfiguration after the first behind the previous bucket's
        # in-flight transfer (the remainder is exposed when a bucket
        # drains faster than the mesh settles).
        t = self.bytes_on_wire(nbytes, n, bits) / WIRE_BYTES_PER_S
        nb = _n_buckets(nbytes, bucket_bytes)
        if not overlap:
            return nb * MESH_RECONFIG_S + t
        t_bucket = t / nb
        return (MESH_RECONFIG_S + t
                + max(0.0, MESH_RECONFIG_S - t_bucket) * (nb - 1))


class CascadeBackend:
    """Two-level carry-cascade (paper III-C eq. 10) over >= 2 mesh axes.

    cfg.axes = (level2_axis, ..., level1_axis): the LAST axis is the
    within-pod level-1 OptINC group; the rest are the cross-pod level-2
    fabric.  Behavioral: level 1 reduce-scatters the B-bit codes and
    keeps the exact integer partial sum (= N1 x the level-1 average at
    resolution 1/N1 — the decimal part d of eq. 10 carried in
    ceil(log4 N1) extra PAM4 symbols, here as dtype headroom); level 2
    sums the carried values and quantizes once, so the result equals the
    one-shot eq. 8 average.  fidelity='onn'|'mesh' runs BOTH levels
    through the emulated fabric instead — two chained
    ``photonics.pipeline`` levels with the eq.-10 carry threaded through
    their Readout/Encode stages (``_photonic_cascade_sync``), bit-exact
    against this behavioral path on a 100%-accuracy ONN.
    """
    name = "cascade"

    def sync(self, flat, cfg, key):
        if len(cfg.axes) == 1:
            # N2 == 1 degenerate cascade (elastic shrink to a single
            # pod): level 2 has nothing to merge, so the exact eq.-10
            # result IS the one-level optinc average over the surviving
            # axis — same quantize/sum/Q(mean) path, same fidelity knobs
            return OptincBackend().sync(flat, cfg, key)
        if len(cfg.axes) < 2:
            raise ValueError(
                "cascade sync needs >= 2 mesh axes (level-2..., level-1), "
                f"got {cfg.axes!r}; run with a (pod, data) mesh")
        ph = getattr(cfg, "photonics", None)
        if ph is not None and ph.fidelity != "behavioral":
            return _photonic_cascade_sync(flat, cfg, key)
        lvl1_ax = cfg.axes[-1]
        lvl2_axes = cfg.axes[:-1]
        n1 = lax.axis_size(lvl1_ax)
        # level 1: within-pod optical sum of B-bit codes in the narrowest
        # type holding the N1-way sum.  The carried code is
        # B + 2*extra_symbols(N1) bits wide on the optical wire; the
        # runtime carries that precision as dtype headroom (bytes_on_wire
        # models the wire width).  Level 2 sums the carried (exact,
        # resolution-1/N1) values across pods in int32, and
        # _quantized_sync quantizes ONCE (eq. 10 == eq. 8).
        max_sum1 = (2 ** cfg.bits - 2) * n1
        l1_dt = jnp.int16 if max_sum1 < 2 ** 15 else jnp.int32
        plan = [(lvl1_ax, l1_dt)] + [(ax, jnp.int32) for ax in lvl2_axes]
        return _quantized_sync(flat, cfg, key, plan)

    def bytes_on_wire(self, nbytes: float, n: int, bits: int,
                      n1: int | None = None) -> float:
        # per-server uplink (B bits/elem) + its amortized share of the
        # level-1 -> level-2 link carrying B + 2*ceil(log4 N1) bits/elem.
        # n1 is the level-1 (per-OptINC) group size; defaults to the
        # paper's balanced sqrt(N) split — pass the actual split when
        # comparing against a measured topology (e.g. fig6's pod=2 mesh).
        if n1 is None:
            n1 = max(int(round(n ** 0.5)), 1)
        if n1 >= n:
            # single-pod (N2 == 1) degenerate cascade: no level-1 -> 2
            # carry link exists — the wire cost is one-level optinc's
            return OptincBackend().bytes_on_wire(nbytes, n, bits)
        elems = nbytes / 2.0
        uplink = elems * bits / 8.0
        carry = elems * (bits + 2 * extra_symbols(n1)) / 8.0 / n1
        return uplink + carry

    def time_on_wire(self, nbytes: float, n: int, bits: int,
                     overlap: bool = False,
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                     n1: int | None = None) -> float:
        # TWO reconfigurable circuits per bucket: the level-0 pod mesh
        # (uplink reduction over n1 servers) and the level-1 carry mesh
        # (cross-pod merge of the eq.-10 partial averages).  Serially
        # (overlap off) every bucket pays program-0, transfer-0,
        # program-1, transfer-1 back to back.  The streaming engine runs
        # the two levels as a 2-stage pipeline — level 0 of bucket k+1
        # reduces WHILE level 1 merges bucket k's carry — and each
        # level's next reconfiguration hides behind its own in-flight
        # transfer, so after the first bucket fills the pipe only the
        # bottleneck stage (transfer or mesh settle, whichever is
        # longer) is exposed per bucket.
        if n1 is None:
            n1 = max(int(round(n ** 0.5)), 1)
        if n1 >= n:
            # single-pod degenerate cascade: one level, optinc timing
            return OptincBackend().time_on_wire(
                nbytes, n, bits, overlap=overlap, bucket_bytes=bucket_bytes)
        elems = nbytes / 2.0
        t0 = elems * bits / 8.0 / WIRE_BYTES_PER_S
        t1 = (elems * (bits + 2 * extra_symbols(n1)) / 8.0 / n1
              / WIRE_BYTES_PER_S)
        nb = _n_buckets(nbytes, bucket_bytes)
        r = MESH_RECONFIG_S
        if not overlap:
            return nb * 2 * r + t0 + t1
        fill = 2 * r + t0 / nb + t1 / nb      # first bucket through both
        drain = max(max(t0 / nb, r), max(t1 / nb, r))
        return fill + (nb - 1) * drain


register_backend("psum", PsumBackend())
register_backend("ring", RingBackend())
register_backend("optinc", OptincBackend())
register_backend("cascade", CascadeBackend())
