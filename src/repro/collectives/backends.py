"""Collective backends: psum | ring | optinc | cascade.

Each backend synchronizes ONE fused f32 bucket inside shard_map (see
bucketizer.py) and models its own wire bytes for the benchmarks
(EXPERIMENTS.md §Fig6).  ``cascade`` is the paper's III-C two-level
carry-cascade (eq. 8-10) made a first-class runtime mode: level-1 OptINCs
reduce over the innermost sync axis and emit the average at resolution
1/N1 — carried losslessly as the integer partial sum, the ICI analogue of
the ``extra_symbols`` higher-precision PAM4 code — and level 2 reduces
across the remaining axes and quantizes ONCE (eq. 10), so the result is
bit-exact against core.cascade.carry_cascade / the one-shot eq. 8 average.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core.cascade import extra_symbols
from ..photonics import error_model
from ..photonics import runtime as ph_runtime
from ..photonics.encoding import (QuantSpec, compute_scale, group_symbols,
                                  pam4_decode, pam4_encode)
from .registry import register_backend

_F32_TINY = 1.1754944e-38  # jnp.finfo(jnp.float32).tiny


def _axis_size(axes) -> int:
    n = 1
    for ax in axes:
        n *= lax.axis_size(ax)
    return n


def _shared_scale(flat: jnp.ndarray, cfg) -> jnp.ndarray:
    """Per-block max-abs scale shared across all peers of cfg.axes (the
    paper's global block quantization, <0.4% sync cost)."""
    spec = QuantSpec(bits=cfg.bits, block=cfg.block)
    scale = compute_scale(flat, spec)
    for ax in cfg.axes:
        scale = lax.pmax(scale, ax)
    return scale


def _encode(flat: jnp.ndarray, scale: jnp.ndarray, cfg):
    """f32 bucket -> offset-binary B-bit codes, zero-block safe.

    An all-zero block (on every peer) leaves ``scale`` at the f32-tiny
    floor; dividing denormal-adjacent values by it can overflow to inf
    before the clip.  Blocks with scale at the floor are short-circuited
    to the zero code instead (regression-tested).
    """
    spec = QuantSpec(bits=cfg.bits, block=cfg.block)
    zero_block = scale <= _F32_TINY
    safe = jnp.where(zero_block, 1.0, scale)
    block = max(cfg.block, 1) if cfg.block > 0 else flat.size
    pad = (-flat.size) % max(block, 1)
    blocks = jnp.pad(flat, (0, pad)).reshape(scale.shape[0], -1)
    q = jnp.round(blocks / safe[:, None] * spec.levels)
    q = jnp.clip(q, -spec.levels, spec.levels).astype(jnp.int32)
    q = jnp.where(zero_block[:, None], 0, q)
    return q + spec.levels, q, safe, spec  # offset-binary u, signed q


def _decode(q_signed: jnp.ndarray, safe_scale: jnp.ndarray, spec,
            size: int) -> jnp.ndarray:
    deq = q_signed.astype(jnp.float32) * (safe_scale[:, None] / spec.levels)
    return deq.reshape(-1)[:size]


class PsumBackend:
    """XLA-native exact all-reduce mean (reference)."""
    name = "psum"

    def sync(self, flat, cfg, key):
        axes = cfg.axes[0] if len(cfg.axes) == 1 else cfg.axes
        return lax.pmean(flat, axes), None

    def bytes_on_wire(self, nbytes: float, n: int, bits: int) -> float:
        # ring-equivalent all-reduce: RS + AG, (N-1)/N of the payload each
        return 2.0 * (n - 1) / max(n, 1) * nbytes


def _ring_allreduce_flat(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Manual ring all-reduce of one bucket over one mesh axis:
    reduce-scatter then all-gather, each via (N-1) ppermute rounds
    (paper Fig. 1)."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    idx = lax.axis_index(axis)
    pad = (-x.shape[0]) % n
    chunks = jnp.pad(x, (0, pad)).reshape(n, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    # Rounds are Python-unrolled so every ppermute appears in the HLO
    # (static collective accounting sees all 2(N-1) rounds) and XLA can
    # overlap consecutive rounds.
    for r in range(n - 1):
        sent = lax.ppermute(chunks[(idx - r) % n], axis, fwd)
        chunks = chunks.at[(idx - r - 1) % n].add(sent)
    for r in range(n - 1):
        sent = lax.ppermute(chunks[(idx + 1 - r) % n], axis, fwd)
        chunks = chunks.at[(idx - r) % n].set(sent)
    return chunks.reshape(-1)[: x.shape[0]]


class RingBackend:
    """Faithful ring all-reduce (the paper's baseline, 2(N-1)/N blow-up)."""
    name = "ring"

    def sync(self, flat, cfg, key):
        out = flat
        for ax in cfg.axes:
            out = _ring_allreduce_flat(out, ax)
        return out / _axis_size(cfg.axes), None

    def bytes_on_wire(self, nbytes: float, n: int, bits: int) -> float:
        return 2.0 * (n - 1) / max(n, 1) * nbytes


def _quantized_sync(flat, cfg, key, scatter_plan):
    """Shared quantize -> integer reduce -> Q(mean) -> dequantize path.

    ``scatter_plan`` is the ordered (axis, int_dtype) reduce-scatter
    schedule; each stage runs in a dtype wide enough for its partial sum.
    The all-gather unwinds the plan in reverse.  Returns
    (synced, local_quantization_error) — the error is what this device's
    transceiver lost encoding its own gradient (error feedback).
    """
    n = _axis_size(cfg.axes)
    scale = _shared_scale(flat, cfg)
    u, q, safe, spec = _encode(flat, scale, cfg)
    flat_u = u.reshape(-1)
    parts = jnp.pad(flat_u, (0, (-flat_u.size) % n))
    for ax, dt in scatter_plan:
        parts = lax.psum_scatter(parts.astype(dt), ax,
                                 scatter_dimension=0, tiled=True)
    # single quantization of the reduced output (eq. 3 / eq. 10)
    u_avg = jnp.round(parts.astype(jnp.float32) / n).astype(jnp.int32)
    if cfg.error_layers and key is not None:
        spec_err = error_model.TABLE_II[tuple(cfg.error_layers)]
        u_avg = error_model.inject(key, u_avg, spec_err, cfg.bits)
    ag_dt = jnp.uint8 if cfg.bits <= 8 else jnp.uint16
    coded = u_avg.astype(ag_dt)
    for ax, _ in reversed(scatter_plan):
        coded = lax.all_gather(coded, ax, axis=0, tiled=True)
    u_avg = coded[: flat_u.size].astype(jnp.int32).reshape(u.shape)
    out = _decode(u_avg - spec.levels, safe, spec, flat.size)
    local = _decode(q, safe, spec, flat.size)
    return out, flat - local


def _photonic_sync(flat, cfg, key):
    """The hardware-in-the-loop OptINC path (fidelity = 'onn' | 'mesh').

    Instead of computing Q(mean) directly in the integer domain, the
    B-bit codes are PAM4-encoded, every peer's symbol stream is gathered
    into the emulated optical fabric, the preprocessing unit P merges
    and averages them (paper III-A), and the averaged-gradient symbols
    come out of the in-network ONN — either its trained dense forward
    pass ('onn') or the phase-programmed MZI mesh emulator itself
    ('mesh', repro.photonics.mesh).  The whole path is ordinary traced
    jax, so it jit-compiles inside ``sync_gradients``.
    """
    n = _axis_size(cfg.axes)
    module = ph_runtime.get_module(cfg.photonics, cfg.bits, n)
    scale = _shared_scale(flat, cfg)
    u, q, safe, spec = _encode(flat, scale, cfg)
    flat_u = u.reshape(-1)
    # unit P, distributed: each transceiver groups its OWN PAM4 symbols
    # into base-4 values locally and the fabric's average is an exact
    # integer psum / N (bit-identical to gathering all N symbol streams
    # and taking preprocess()'s mean, without the N x memory blowup)
    sym = pam4_encode(flat_u, cfg.bits)                        # (L, M)
    vals = group_symbols(sym, cfg.bits, module.cfg.k_inputs)   # (L, K)
    total = vals.astype(jnp.float32)
    for ax in cfg.axes:
        total = lax.psum(total, ax)
    a = total / n                                   # unit P output (L, K)
    out_sym = module.symbols(a, fidelity=cfg.photonics.fidelity,
                             mesh_backend=cfg.photonics.mesh_backend)
    u_avg = pam4_decode(out_sym)                         # (L,) int32
    if cfg.error_layers and key is not None:
        spec_err = error_model.TABLE_II[tuple(cfg.error_layers)]
        u_avg = error_model.inject(key, u_avg, spec_err, cfg.bits)
    out = _decode(u_avg.reshape(u.shape) - spec.levels, safe, spec,
                  flat.size)
    local = _decode(q, safe, spec, flat.size)
    return out, flat - local


class OptincBackend:
    """Quantize -> integer in-network sum -> Q(mean) -> dequantize.

    ``cfg.photonics.fidelity`` selects the emulation depth: 'behavioral'
    keeps the TPU ICI analogue of the optical sum at symbol width
    (reduce-scatter the B-bit codes in the narrowest integer type holding
    the N-way sum, apply the ONN transfer function Q(mean) on the
    scattered shard (eq. 3), all-gather the B-bit result); 'onn' / 'mesh'
    run the gathered symbol streams through the in-network ONN itself
    (``_photonic_sync``).
    """
    name = "optinc"

    def sync(self, flat, cfg, key):
        ph = getattr(cfg, "photonics", None)
        if ph is not None and ph.fidelity != "behavioral":
            return _photonic_sync(flat, cfg, key)
        n = _axis_size(cfg.axes)
        max_sum = (2 ** cfg.bits - 2) * n
        rs_dt = jnp.int16 if max_sum < 2 ** 15 else jnp.int32
        plan = [(ax, rs_dt) for ax in cfg.axes]
        return _quantized_sync(flat, cfg, key, plan)

    def bytes_on_wire(self, nbytes: float, n: int, bits: int) -> float:
        # one send of the B-bit codes into the optical fabric per server
        # (receive is symmetric; send-direction accounting)
        return (nbytes / 2.0) * bits / 8.0


class CascadeBackend:
    """Two-level carry-cascade (paper III-C eq. 10) over >= 2 mesh axes.

    cfg.axes = (level2_axis, ..., level1_axis): the LAST axis is the
    within-pod level-1 OptINC group; the rest are the cross-pod level-2
    fabric.  Level 1 reduce-scatters the B-bit codes and keeps the exact
    integer partial sum (= N1 x the level-1 average at resolution 1/N1 —
    the decimal part d of eq. 10 carried in ceil(log4 N1) extra PAM4
    symbols, here as dtype headroom).  Level 2 sums the carried values
    and quantizes once, so the result equals the one-shot eq. 8 average.
    """
    name = "cascade"

    def sync(self, flat, cfg, key):
        if len(cfg.axes) < 2:
            raise ValueError(
                "cascade sync needs >= 2 mesh axes (level-2..., level-1), "
                f"got {cfg.axes!r}; run with a (pod, data) mesh")
        ph = getattr(cfg, "photonics", None)
        if ph is not None and ph.fidelity != "behavioral":
            raise ValueError(
                "the cascade backend is behavioral-only; use mode='optinc' "
                f"for fidelity={ph.fidelity!r}")
        lvl1_ax = cfg.axes[-1]
        lvl2_axes = cfg.axes[:-1]
        n1 = lax.axis_size(lvl1_ax)
        # level 1: within-pod optical sum of B-bit codes in the narrowest
        # type holding the N1-way sum.  The carried code is
        # B + 2*extra_symbols(N1) bits wide on the optical wire; the
        # runtime carries that precision as dtype headroom (bytes_on_wire
        # models the wire width).  Level 2 sums the carried (exact,
        # resolution-1/N1) values across pods in int32, and
        # _quantized_sync quantizes ONCE (eq. 10 == eq. 8).
        max_sum1 = (2 ** cfg.bits - 2) * n1
        l1_dt = jnp.int16 if max_sum1 < 2 ** 15 else jnp.int32
        plan = [(lvl1_ax, l1_dt)] + [(ax, jnp.int32) for ax in lvl2_axes]
        return _quantized_sync(flat, cfg, key, plan)

    def bytes_on_wire(self, nbytes: float, n: int, bits: int,
                      n1: int | None = None) -> float:
        # per-server uplink (B bits/elem) + its amortized share of the
        # level-1 -> level-2 link carrying B + 2*ceil(log4 N1) bits/elem.
        # n1 is the level-1 (per-OptINC) group size; defaults to the
        # paper's balanced sqrt(N) split — pass the actual split when
        # comparing against a measured topology (e.g. fig6's pod=2 mesh).
        if n1 is None:
            n1 = max(int(round(n ** 0.5)), 1)
        elems = nbytes / 2.0
        uplink = elems * bits / 8.0
        carry = elems * (bits + 2 * extra_symbols(n1)) / 8.0 / n1
        return uplink + carry


register_backend("psum", PsumBackend())
register_backend("ring", RingBackend())
register_backend("optinc", OptincBackend())
register_backend("cascade", CascadeBackend())
