"""Parameter resolution + checkpoint hot-swap for the serving tier.

``resolve_params`` is the single "where do serving weights come from"
decision (shared by ServeSession and ServeEngine): the spec's checkpoint
directory when ``ckpt.dir`` + ``ckpt.resume`` are set, else a fresh
seeded init.  ``ParamReloader`` polls the same directory for a NEWER
step between decode steps so a live engine picks up a concurrently
training run's checkpoints without a restart — the swap is atomic from
the model's point of view because it happens on the host between jitted
decode calls (a step runs entirely on the old or entirely on the new
params, never a mix).

repro.api is imported function-locally: api.spec imports
serving.config, so a module-level import here would cycle.
"""
from __future__ import annotations

import os

import jax

from ..checkpoint.ckpt import latest_step, load_checkpoint
from ..models import lm


def load_params(spec, cfg, mesh, step: int):
    """Params of checkpoint ``step`` placed with spec's sharding.
    load_checkpoint only reads the template's structure and dtypes — an
    eval_shape template skips materializing a throwaway init."""
    from ..api import build
    ctx = spec.mesh.ctx()
    template = jax.eval_shape(
        lambda: lm.init_params(cfg, ctx, jax.random.PRNGKey(0)))
    p_specs, _ = build.param_specs(spec, cfg)
    tree, _ = load_checkpoint(spec.ckpt.dir, step, {"params": template},
                              mesh=mesh, specs={"params": p_specs})
    return tree["params"]


def resolve_params(spec, cfg, mesh):
    """(params, checkpoint_step | None): newest checkpoint when the spec
    asks to resume from one, else a fresh seeded init."""
    c = spec.ckpt
    step = latest_step(c.dir) if (c.dir and c.resume) else None
    if step is None:
        return lm.init_params(cfg, spec.mesh.ctx(),
                              jax.random.PRNGKey(spec.seed)), None
    print(f"serving params from checkpoint step {step}", flush=True)
    return load_params(spec, cfg, mesh, step), step


class ParamReloader:
    """Hot-swap poller over ``spec.ckpt.dir``.

    ``poll()`` returns (params, step) when a checkpoint newer than
    ``current_step`` has appeared (None while nothing changed); partial
    writes are invisible because ``save_checkpoint`` os.replace()'s the
    step directory atomically and ``latest_step`` skips anything without
    a readable manifest.

    The idle path costs one ``os.stat``: a new checkpoint necessarily
    changes the directory's mtime (``os.replace`` of the step dir into
    it), so the manifest listing/parsing only runs when the stat says
    something moved.  The stat is taken BEFORE the listing — a
    checkpoint landing between the two is seen by this poll or bumps the
    mtime past the recorded one, never silently skipped.
    """

    def __init__(self, spec, cfg, mesh, current_step=None):
        if not spec.ckpt.dir:
            raise ValueError("ParamReloader needs spec.ckpt.dir")
        self.spec = spec
        self.cfg = cfg
        self.mesh = mesh
        self.current_step = -1 if current_step is None else current_step
        self._dir_mtime_ns = None

    def poll(self):
        try:
            mtime = os.stat(self.spec.ckpt.dir).st_mtime_ns
        except OSError:
            return None  # directory not created yet — nothing to swap to
        if mtime == self._dir_mtime_ns:
            return None
        step = latest_step(self.spec.ckpt.dir)
        self._dir_mtime_ns = mtime
        if step is None or step <= self.current_step:
            return None
        params = load_params(self.spec, self.cfg, self.mesh, step)
        self.current_step = step
        return params, step
