"""repro.serving: continuous-batching inference tier.

- ``config``    — frozen ServeConfig (embedded in RunSpec as ``serve``)
- ``kv_pool``   — paged KV cache: page pool, allocator, prompt scatter
- ``scheduler`` — admission / growth / preemption bookkeeping
- ``engine``    — ServeEngine: one jitted decode step over the packed
                  active batch (loaded lazily: it imports repro.api)
- ``reload``    — param resolution + checkpoint hot-swap (lazy, same)
"""
from .config import ServeConfig
from .kv_pool import NULL_PAGE, PageAllocator, init_pool, pool_specs, \
    supports_paged, write_prompt, write_prompts
from .scheduler import QueueFull, Request, Scheduler, Sequence

__all__ = [
    "ServeConfig", "NULL_PAGE", "PageAllocator", "init_pool", "pool_specs",
    "supports_paged", "write_prompt", "write_prompts", "QueueFull",
    "Request", "Scheduler", "Sequence", "ServeEngine", "ParamReloader",
    "load_params", "resolve_params",
]

_LAZY = {"ServeEngine": "engine",
         "ParamReloader": "reload",
         "load_params": "reload",
         "resolve_params": "reload"}


def __getattr__(name):
    # engine/reload import repro.api (which imports serving.config);
    # loading them lazily keeps `import repro.serving` cycle-free
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
