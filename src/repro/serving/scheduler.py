"""Continuous-batching scheduler: admission, growth, preemption.

Pure host-side bookkeeping — no jax.  The engine drives it once per
decode step: ``admit()`` pulls queued requests into free slots while
pages last (FCFS with head-of-line blocking so long prompts cannot
starve), ``grow()`` extends a sequence's page table when it crosses a
page boundary, and when the pool runs dry the engine preempts the
youngest sequence — its pages are freed and the request re-queued at the
FRONT with its generated tokens kept, so re-admission prefills
prompt + generated and continues exactly where it left off.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from .config import ServeConfig
from .kv_pool import PageAllocator


class QueueFull(RuntimeError):
    """submit() would exceed ServeConfig.max_queue."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Sequence:
    """An admitted request bound to physical pages.  ``length`` counts
    cache entries written so far (prompt + generated tokens whose KV is
    in the pool); ``last_token`` is the next decode input."""
    req: Request
    pages: list
    length: int = 0
    last_token: int = 0


class Scheduler:
    def __init__(self, cfg: ServeConfig, alloc: PageAllocator):
        self.cfg = cfg
        self.alloc = alloc
        self.queue: deque = deque()
        self.active: list = []    # index == engine slot row
        self.n_preempted = 0
        self._next_rid = 0

    @property
    def capacity(self) -> int:
        return self.cfg.capacity

    @property
    def max_blocks(self) -> int:
        return self.cfg.max_blocks

    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def submit(self, prompt, max_new_tokens=None) -> int:
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        mnt = self.cfg.max_new_tokens if max_new_tokens is None \
            else int(max_new_tokens)
        if mnt < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {mnt}")
        if len(prompt) + mnt > self.cfg.capacity:
            raise ValueError(
                f"prompt ({len(prompt)}) + budget ({mnt}) exceeds "
                f"serve.max_seq capacity ({self.cfg.capacity})")
        if len(self.queue) >= self.cfg.max_queue:
            raise QueueFull(f"serve.max_queue={self.cfg.max_queue}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, mnt))
        return rid

    def admit(self) -> list:
        """Move queued requests into free slots while pages last.
        Returns the newly-admitted Sequences (engine must prefill them)."""
        new = []
        while self.queue and len(self.active) < self.cfg.max_active:
            req = self.queue[0]
            feed = len(req.prompt) + len(req.generated)
            nb = -(-feed // self.cfg.page_size)
            pages = self.alloc.alloc(nb)
            if pages is None:
                break  # head-of-line blocking: keep FCFS order
            self.queue.popleft()
            seq = Sequence(req, pages, length=feed)
            self.active.append(seq)
            new.append(seq)
        return new

    def grow(self, seq: Sequence) -> bool:
        """Ensure seq has a page for the cache entry at index
        ``seq.length`` (the token about to be decoded).  False = pool
        exhausted; caller must preempt someone."""
        blk = seq.length // self.cfg.page_size
        if blk < len(seq.pages):
            return True
        got = self.alloc.alloc(1)
        if got is None:
            return False
        seq.pages.extend(got)
        return True

    def preempt_youngest(self) -> Sequence:
        """Evict the most recently admitted sequence: free its pages and
        push its request back to the FRONT of the queue (generated
        tokens kept, so re-admission resumes exactly)."""
        seq = self.active.pop()
        self.alloc.free(seq.pages)
        seq.pages = []
        self.queue.appendleft(seq.req)
        self.n_preempted += 1
        return seq

    def finish(self, seq: Sequence) -> Request:
        self.active.remove(seq)
        self.alloc.free(seq.pages)
        seq.pages = []
        return seq.req
