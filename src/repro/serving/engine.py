"""ServeEngine: continuous-batching decode over the paged KV pool.

One jitted decode step advances EVERY active sequence by one token:
admitted sequences prefill through the compiled prefill step (their KV
scattered into freshly-allocated pages), then join the packed slot
batch.  Sequences finish (budget / stop token) and new arrivals are
admitted between steps, so the batch membership changes continuously —
the classic continuous-batching loop, vs. ServeSession.generate's
static batch.

The packed batch is padded to a power-of-two bucket (capped at
``max_active``) so the decode step retraces O(log max_active) times,
not once per occupancy.  Inactive pad rows carry length 0 and an
all-null page table: they scatter into / gather from the reserved null
page and their logits are discarded.

repro.api is imported function-locally (api.spec imports
serving.config — a module-level import here would cycle).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models import lm
from . import kv_pool, reload
from .scheduler import Scheduler, Sequence


class ServeEngine:
    def __init__(self, spec, params=None):
        from ..api import build
        spec.validate()
        self.spec = spec
        self.scfg = spec.serve
        self.cfg = spec.model_config()
        if not kv_pool.supports_paged(self.cfg):
            raise NotImplementedError(
                f"paged serving covers the dense-attention families; "
                f"{self.cfg.name} (ssm/enc-dec/moe) serves through "
                f"ServeSession instead")
        if spec.mesh.dp * spec.mesh.pods != 1:
            raise NotImplementedError(
                "ServeEngine shards over 'model' only (prefill runs one "
                "sequence at a time and decode occupancy is dynamic — "
                "neither can keep a data axis busy); use a 1xTP mesh")
        self.mesh = spec.mesh.build()
        # decode-path ctx: SP/remat are train-time concerns (mirrors
        # make_decode_step, which never enables them)
        ctx = dataclasses.replace(spec.mesh.ctx(), seq_parallel=False,
                                  remat_groups=0)
        self.ctx = ctx

        if params is not None:
            self.params, self.params_step = params, None
        else:
            self.params, self.params_step = reload.resolve_params(
                spec, self.cfg, self.mesh)
        self.reloader = None
        if spec.ckpt.dir and self.scfg.reload_every > 0:
            self.reloader = reload.ParamReloader(
                spec, self.cfg, self.mesh, current_step=self.params_step)

        n_pages = self.scfg.auto_pages()
        with jax.set_mesh(self.mesh):
            self.pool = kv_pool.init_pool(self.cfg, ctx, n_pages,
                                          self.scfg.page_size)
        self.sched = Scheduler(self.scfg, kv_pool.PageAllocator(n_pages))

        pre, _, _ = build.build_prefill_step(spec, self.cfg, self.mesh)
        self._prefill = jax.jit(pre)
        p_specs = lm.flat_specs(self.cfg, ctx)
        pspec = kv_pool.pool_specs(ctx)

        def step(params, pool, page_table, lengths, token):
            return lm.paged_decode_step(self.cfg, ctx, params, pool,
                                        page_table, lengths, token)

        self._decode = jax.jit(
            jax.shard_map(step, mesh=self.mesh,
                          in_specs=(p_specs, pspec, P(None, None), P(None),
                                    P(None, None)),
                          out_specs=(P(None, ctx.model_axis), pspec),
                          check_vma=False),
            donate_argnums=(1,))
        self._write_prompt = jax.jit(kv_pool.write_prompt,
                                     donate_argnums=(0,))

        self.results: dict = {}      # rid -> list of generated token ids
        self.step_count = 0
        self.max_observed_active = 0

    # -------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens=None) -> int:
        return self.sched.submit(prompt, max_new_tokens)

    def has_work(self) -> bool:
        return self.sched.has_work()

    # ---------------------------------------------------------------- step
    def step(self):
        """Advance every active sequence by one token.  Returns the list
        of (rid, token) pairs emitted this step (prefill first-tokens of
        newly admitted sequences included)."""
        self.step_count += 1
        if (self.reloader is not None
                and self.step_count % self.scfg.reload_every == 0):
            swapped = self.reloader.poll()
            if swapped is not None:
                self.params, self.params_step = swapped
                print(f"hot-swapped params to checkpoint step "
                      f"{self.params_step}", flush=True)
        emitted = []
        with jax.set_mesh(self.mesh):
            for seq in self.sched.admit():
                emitted += self._prefill_seq(seq)
            self._ensure_growth()
            act = self.sched.active
            self.max_observed_active = max(self.max_observed_active, len(act))
            if not act:
                return emitted
            b = min(max(1, 1 << (len(act) - 1).bit_length()),
                    self.scfg.max_active)
            pt = np.zeros((b, self.scfg.max_blocks), np.int32)
            ln = np.zeros((b,), np.int32)
            tok = np.zeros((b, 1), np.int32)
            for i, seq in enumerate(act):
                pt[i, :len(seq.pages)] = seq.pages
                ln[i] = seq.length
                tok[i, 0] = seq.last_token
            logits, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(pt), jnp.asarray(ln),
                jnp.asarray(tok))
            toks = self._sample(logits[:len(act)], act)
        for seq, t in zip(list(act), toks):
            seq.length += 1
            emitted += self._push_token(seq, int(t))
        return emitted

    def _ensure_growth(self):
        """Every active sequence gets a page for its next cache entry;
        when the pool runs dry the youngest sequences are preempted
        (pages freed, request re-queued with its generated tokens) until
        the remaining ones fit."""
        i = 0
        while i < len(self.sched.active):
            seq = self.sched.active[i]
            if self.sched.grow(seq):
                i += 1
                continue
            victim = self.sched.preempt_youngest()
            if victim is seq:  # even alone it can't grow — re-queued
                break

    def _prefill_seq(self, seq: Sequence):
        """Compiled prefill over prompt + any previously generated tokens
        (preemption resume), KV scattered into the sequence's pages, and
        the first token sampled from the prefill logits."""
        req = seq.req
        feed = req.prompt + req.generated
        logits, pkv = self._prefill(
            self.params, {"tokens": jnp.asarray([feed], jnp.int32)})
        self.pool = self._write_prompt(self.pool, pkv,
                                       jnp.asarray(seq.pages, jnp.int32))
        t = int(self._sample(logits, [seq])[0])
        return self._push_token(seq, t)

    def _push_token(self, seq: Sequence, tok: int):
        seq.req.generated.append(tok)
        seq.last_token = tok
        if self._stopped(seq):
            req = self.sched.finish(seq)
            self.results[req.rid] = list(req.generated)
        return [(seq.req.rid, tok)]

    def _stopped(self, seq: Sequence) -> bool:
        req = seq.req
        return (len(req.generated) >= req.max_new_tokens
                or seq.last_token == self.scfg.stop_token
                or seq.length >= self.scfg.capacity)

    # -------------------------------------------------------------- sample
    def _sample(self, logits, seqs):
        logits = logits[:, :self.cfg.vocab]
        if self.scfg.temperature == 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        out = []
        for row, seq in zip(logits, seqs):
            # per-(request, position) key: deterministic under preemption
            # and re-batching
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.spec.seed),
                                   seq.req.rid),
                len(seq.req.generated))
            row = row / self.scfg.temperature
            if self.scfg.top_k:
                kth = jnp.sort(row)[-self.scfg.top_k]
                row = jnp.where(row < kth, -jnp.inf, row)
            out.append(int(jax.random.categorical(key, row)))
        return np.asarray(out)

    # --------------------------------------------------------------- drive
    def serve(self, prompts, max_new_tokens=None) -> dict:
        """Submit a batch of prompts and run the engine to drain.
        Returns {rid: np.ndarray of generated token ids}."""
        rids = [self.submit(p, max_new_tokens) for p in prompts]
        while self.has_work():
            self.step()
        return {rid: np.asarray(self.results[rid]) for rid in rids}
