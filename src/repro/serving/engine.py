"""ServeEngine: continuous-batching decode over the paged KV pool.

One jitted decode step advances EVERY active sequence by one token:
admitted sequences prefill through ONE batched prefill launch (all of a
step's admissions packed into a padded prompt batch, their KV scattered
into freshly-allocated pages), then join the packed slot batch.
Sequences finish (budget / stop token) and new arrivals are admitted
between steps, so the batch membership changes continuously — the
classic continuous-batching loop, vs. ServeSession.generate's static
batch.

Both launches bucket their dynamic dimensions to powers of two so the
jitted programs retrace O(log) times, not once per shape: the decode
batch pads to a pow2 occupancy bucket (capped at ``max_active``), the
prefill batch pads rows the same way and prompt lengths to pow2
page-aligned buckets.  Inactive pad rows carry length 0 and an all-null
page table: they scatter into / gather from the reserved null page and
their logits are discarded.

Batched prefill shards its rows over the DP axes
(steps.make_batched_prefill_step), so dp > 1 serving meshes are legal:
prefill keeps the data axis busy while the decode step — whose packed
batch is occupancy-dynamic — runs replicated over 'data' (its inputs
carry no data-axis spec, every data shard computes identical tokens).

``ServeConfig.decode_backend`` picks the decode attention path
('gather' copies pages contiguous, 'paged' attends over the pool in
place — kernels.paged_attention on TPU, bit-exact gather fallback
elsewhere); ``ServeConfig.kv_dtype`` picks the pool storage dtype.

repro.api is imported function-locally (api.spec imports
serving.config — a module-level import here would cycle).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models import lm
from . import kv_pool, reload
from .scheduler import Scheduler, Sequence


class ServeEngine:
    def __init__(self, spec, params=None):
        from ..api import build
        spec.validate()
        self.spec = spec
        self.scfg = spec.serve
        self.cfg = spec.model_config()
        if not kv_pool.supports_paged(self.cfg):
            raise NotImplementedError(
                f"paged serving covers the dense-attention families; "
                f"{self.cfg.name} (ssm/enc-dec/moe) serves through "
                f"ServeSession instead")
        self.mesh = spec.mesh.build()
        # decode-path ctx: SP/remat are train-time concerns (mirrors
        # make_decode_step, which never enables them)
        ctx = dataclasses.replace(spec.mesh.ctx(), seq_parallel=False,
                                  remat_groups=0)
        self.ctx = ctx

        if params is not None:
            self.params, self.params_step = params, None
        else:
            self.params, self.params_step = reload.resolve_params(
                spec, self.cfg, self.mesh)
        self.reloader = None
        if spec.ckpt.dir and self.scfg.reload_every > 0:
            self.reloader = reload.ParamReloader(
                spec, self.cfg, self.mesh, current_step=self.params_step)

        n_pages = self.scfg.auto_pages()
        pspec = kv_pool.pool_specs(ctx)
        with jax.set_mesh(self.mesh):
            self.pool = kv_pool.init_pool(self.cfg, ctx, n_pages,
                                          self.scfg.page_size,
                                          kv_dtype=self.scfg.kv_dtype)
        # pin the pool to its steady-state sharding (the decode step's
        # out_specs) up front: _write_prompts' jit cache keys on input
        # sharding, so a fresh-from-init pool must not look different
        # from one that has been through a decode step
        from jax.sharding import NamedSharding
        self.pool = jax.device_put(
            self.pool, jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                                    pspec))
        self.sched = Scheduler(self.scfg, kv_pool.PageAllocator(n_pages))

        pre, _, _ = build.build_batched_prefill_step(spec, self.cfg,
                                                     self.mesh)
        self._prefill = jax.jit(pre)
        p_specs = lm.flat_specs(self.cfg, ctx)

        def step(params, pool, page_table, lengths, token):
            return lm.paged_decode_step(
                self.cfg, ctx, params, pool, page_table, lengths, token,
                decode_backend=self.scfg.decode_backend)

        self._decode = jax.jit(
            jax.shard_map(step, mesh=self.mesh,
                          in_specs=(p_specs, pspec, P(None, None), P(None),
                                    P(None, None)),
                          out_specs=(P(None, ctx.model_axis), pspec),
                          check_vma=False),
            donate_argnums=(1,))
        self._write_prompts = jax.jit(kv_pool.write_prompts,
                                      donate_argnums=(0,))

        self.results: dict = {}      # rid -> list of generated token ids
        self.step_count = 0
        self.max_observed_active = 0

    # -------------------------------------------------------------- intake
    def submit(self, prompt, max_new_tokens=None) -> int:
        return self.sched.submit(prompt, max_new_tokens)

    def has_work(self) -> bool:
        return self.sched.has_work()

    # ---------------------------------------------------------------- step
    def step(self):
        """Advance every active sequence by one token.  Returns the list
        of (rid, token) pairs emitted this step (prefill first-tokens of
        newly admitted sequences included)."""
        self.step_count += 1
        if (self.reloader is not None
                and self.step_count % self.scfg.reload_every == 0):
            swapped = self.reloader.poll()
            if swapped is not None:
                self.params, self.params_step = swapped
                print(f"hot-swapped params to checkpoint step "
                      f"{self.params_step}", flush=True)
        emitted = []
        with jax.set_mesh(self.mesh):
            admitted = self.sched.admit()
            if admitted:
                emitted += self._prefill_batch(admitted)
            self._ensure_growth()
            act = self.sched.active
            self.max_observed_active = max(self.max_observed_active, len(act))
            if not act:
                return emitted
            b = min(max(1, 1 << (len(act) - 1).bit_length()),
                    self.scfg.max_active)
            pt = np.zeros((b, self.scfg.max_blocks), np.int32)
            ln = np.zeros((b,), np.int32)
            tok = np.zeros((b, 1), np.int32)
            for i, seq in enumerate(act):
                pt[i, :len(seq.pages)] = seq.pages
                ln[i] = seq.length
                tok[i, 0] = seq.last_token
            logits, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(pt), jnp.asarray(ln),
                jnp.asarray(tok))
            toks = self._sample(logits[:len(act)], act)
        for seq, t in zip(list(act), toks):
            seq.length += 1
            emitted += self._push_token(seq, int(t))
        return emitted

    def _ensure_growth(self):
        """Every active sequence gets a page for its next cache entry;
        when the pool runs dry the youngest sequences are preempted
        (pages freed, request re-queued with its generated tokens) until
        the remaining ones fit."""
        i = 0
        while i < len(self.sched.active):
            seq = self.sched.active[i]
            if self.sched.grow(seq):
                i += 1
                continue
            victim = self.sched.preempt_youngest()
            if victim is seq:  # even alone it can't grow — re-queued
                break

    def _len_bucket(self, t: int) -> int:
        """Prompt-length bucket: pow2 rounded up to a whole number of
        pages, capped at capacity — one compiled prefill per bucket."""
        ps = self.scfg.page_size
        tb = -(-max(ps, 1 << (t - 1).bit_length()) // ps) * ps
        return min(tb, self.scfg.capacity)

    def _row_bucket(self, n: int) -> int:
        """Prefill row bucket: the decode occupancy bucketing (pow2,
        capped at max_active), rounded up to a multiple of the DP degree
        so the batch axis shards evenly under dp > 1 meshes."""
        b = min(max(1, 1 << (n - 1).bit_length()), self.scfg.max_active)
        dpt = self.spec.mesh.dp * self.spec.mesh.pods
        return -(-max(b, n) // dpt) * dpt

    def _prefill_batch(self, seqs):
        """ONE padded prefill launch for every sequence admitted this
        step: prompts (+ previously generated tokens — preemption
        resume) right-padded into a pow2 page-aligned length bucket,
        rows padded to the occupancy bucket, each row's KV scattered
        into its own pages and its first token sampled from its own
        last-position logits.  Pad rows carry length 0: write_prompts
        drops their KV and their logits are discarded."""
        feeds = [s.req.prompt + s.req.generated for s in seqs]
        n = len(feeds)
        tb = self._len_bucket(max(len(f) for f in feeds))
        bb = self._row_bucket(n)
        tok = np.zeros((bb, tb), np.int32)
        ln = np.zeros((bb,), np.int32)
        pt = np.zeros((bb, tb // self.scfg.page_size), np.int32)
        for i, (seq, feed) in enumerate(zip(seqs, feeds)):
            tok[i, :len(feed)] = feed
            ln[i] = len(feed)
            pt[i, :len(seq.pages)] = seq.pages
        ln = jnp.asarray(ln)
        logits, pkv = self._prefill(self.params, jnp.asarray(tok), ln)
        self.pool = self._write_prompts(self.pool, pkv, jnp.asarray(pt), ln)
        emitted = []
        for seq, t in zip(seqs, self._sample(logits[:n], seqs)):
            emitted += self._push_token(seq, int(t))
        return emitted

    def _push_token(self, seq: Sequence, tok: int):
        seq.req.generated.append(tok)
        seq.last_token = tok
        if self._stopped(seq):
            req = self.sched.finish(seq)
            self.results[req.rid] = list(req.generated)
        return [(seq.req.rid, tok)]

    def _stopped(self, seq: Sequence) -> bool:
        req = seq.req
        return (len(req.generated) >= req.max_new_tokens
                or seq.last_token == self.scfg.stop_token
                or seq.length >= self.scfg.capacity)

    # -------------------------------------------------------------- sample
    def _sample(self, logits, seqs):
        logits = logits[:, :self.cfg.vocab]
        if self.scfg.temperature == 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        out = []
        for row, seq in zip(logits, seqs):
            # per-(request, position) key: deterministic under preemption
            # and re-batching
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.spec.seed),
                                   seq.req.rid),
                len(seq.req.generated))
            row = row / self.scfg.temperature
            if self.scfg.top_k:
                kth = jnp.sort(row)[-self.scfg.top_k]
                row = jnp.where(row < kth, -jnp.inf, row)
            out.append(int(jax.random.categorical(key, row)))
        return np.asarray(out)

    # --------------------------------------------------------------- drive
    def serve(self, prompts, max_new_tokens=None) -> dict:
        """Submit a batch of prompts and run the engine to drain.
        Returns {rid: np.ndarray of generated token ids}."""
        rids = [self.submit(p, max_new_tokens) for p in prompts]
        while self.has_work():
            self.step()
        return {rid: np.asarray(self.results[rid]) for rid in rids}
