"""ServeConfig: the frozen serving-tier half of a RunSpec.

Lives in its own module (no repro.api imports) so ``api.spec`` can embed
it in RunSpec without a cycle: spec -> serving.config only.  Field checks
raise ValueError from ``__post_init__`` — ``_from_dict`` wraps those in
SpecError on the JSON path, and RunSpec.validate() adds the cross-field
rules.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching inference-tier knobs.

    ``max_seq`` bounds prompt + generation per sequence; the page table is
    ``ceil(max_seq / page_size)`` blocks wide.  ``pages`` sizes the shared
    physical KV pool (0 = auto: every slot can hold a full max_seq plus
    the reserved null page — no preemption possible; smaller values admit
    optimistically and preempt under pressure).  ``reload_every`` polls
    ``ckpt.dir`` for a newer checkpoint every N engine steps (hot-swap).

    ``decode_backend`` selects the decode attention path: 'gather'
    materializes each slot's pages contiguous before attending, 'paged'
    attends over the pool in place through the Pallas kernel
    (kernels.paged_attention — compiled on TPU, falls back to the
    bit-exact gather math elsewhere).  ``kv_dtype`` is the pool storage
    dtype: 'auto' follows the model dtype, 'bf16' halves pool bytes and
    page-read traffic (attention still accumulates f32), 'f32' stores
    full precision regardless of model dtype.
    """
    page_size: int = 16       # tokens per KV page
    max_active: int = 8       # concurrently decoding sequences (slots)
    max_queue: int = 64       # queued-but-not-admitted request cap
    max_seq: int = 256        # per-sequence cache capacity (prompt + gen)
    max_new_tokens: int = 64  # default per-request generation budget
    stop_token: int = -1      # end-of-sequence token id (-1 = none)
    temperature: float = 0.0  # 0 = greedy argmax
    top_k: int = 0            # sample from the k best logits (0 = full vocab)
    pages: int = 0            # physical KV pool size in pages (0 = auto)
    reload_every: int = 0     # hot-swap poll period in engine steps (0 = off)
    decode_backend: str = "gather"  # 'gather' | 'paged' (Pallas kernel)
    kv_dtype: str = "auto"    # KV pool storage: 'auto' | 'f32' | 'bf16'

    def __post_init__(self):
        if self.decode_backend not in ("gather", "paged"):
            raise ValueError(f"serve.decode_backend must be 'gather' or "
                             f"'paged', got {self.decode_backend!r}")
        if self.kv_dtype not in ("auto", "f32", "bf16"):
            raise ValueError(f"serve.kv_dtype must be 'auto', 'f32' or "
                             f"'bf16', got {self.kv_dtype!r}")
        for name in ("page_size", "max_active", "max_queue", "max_seq",
                     "max_new_tokens"):
            if getattr(self, name) < 1:
                raise ValueError(f"serve.{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        for name in ("temperature", "top_k", "pages", "reload_every"):
            if getattr(self, name) < 0:
                raise ValueError(f"serve.{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        if self.stop_token < -1:
            raise ValueError(f"serve.stop_token must be a token id or -1, "
                             f"got {self.stop_token}")

    @property
    def max_blocks(self) -> int:
        """Page-table width: logical blocks per sequence."""
        return -(-self.max_seq // self.page_size)

    @property
    def capacity(self) -> int:
        """Tokens one sequence's page table can address."""
        return self.max_blocks * self.page_size

    def auto_pages(self) -> int:
        """Pool size when ``pages`` is 0: one null page + a full page
        table per slot (pressure-free)."""
        return self.pages or 1 + self.max_active * self.max_blocks
