"""Paged KV cache: a shared physical page pool + a free-list allocator.

The contiguous decode cache (``lm.init_cache``) allocates ``max_seq``
slots per sequence up front; the paged pool instead holds one flat axis
of fixed-size pages shared by every active sequence, addressed through
per-sequence page tables.  Memory scales with TOKENS IN FLIGHT, not with
``max_active * max_seq``.

Layout per K/V leaf: ``(L, P, hkv_local, page_size, hd)`` — the dense
family's ``(L, b, hkv_local, max_seq, hd)`` cache with the (batch, seq)
dims replaced by one physical page axis.  Sharding follows the same
``ShardCtx`` convention (kv heads over 'model'); the page axis is never
sharded, so tp layouts keep working unchanged.

**Page 0 is the reserved null page**: fresh page tables point every block
at it, so inactive slot rows and not-yet-allocated blocks scatter/gather
into it harmlessly (its contents are finite garbage, masked to exactly
zero weight by ``decode_attention``'s validity test).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import lm
from ..models.config import ModelConfig
from ..models.layers import ShardCtx

NULL_PAGE = 0


def supports_paged(cfg: ModelConfig) -> bool:
    """Paged serving covers the dense-attention transformer families
    (cache tree {"layers": {"k", "v"}}); recurrent / enc-dec / MoE
    caches stay on the contiguous ServeSession path."""
    return not (cfg.ssm or cfg.enc_dec or cfg.moe)


class PageAllocator:
    """All-or-nothing free-list allocator over page ids 1..n_pages-1
    (page 0 is reserved as the null page, never handed out)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (page 0 is the "
                             f"reserved null page), got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))  # pop() serves low ids
        self._used: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list | None:
        """n distinct pages, or None — never a partial allocation."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._used:
                raise ValueError(f"freeing page {p} that is not allocated "
                                 f"(double free or null page)")
            self._used.discard(p)
            self._free.append(p)


def init_pool(cfg: ModelConfig, ctx: ShardCtx, n_pages: int,
              page_size: int, kv_dtype: str = "auto"):
    """Zeroed physical page pool, dense-family layout (see module doc).
    ``kv_dtype`` is ServeConfig.kv_dtype: 'auto' follows the model dtype;
    'bf16' halves pool bytes (decode_attention and the paged kernel both
    accumulate f32 regardless of storage dtype); 'f32' stores full
    precision."""
    assert supports_paged(cfg), cfg.name
    dims = lm.ArchDims.build(cfg, ctx)
    kvl = dims.kv_pad // ctx.tp
    dt = {"auto": jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
          "f32": jnp.float32, "bf16": jnp.bfloat16}[kv_dtype]
    shape = (cfg.n_layers, n_pages, kvl, page_size, cfg.hd)
    return {"layers": {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}}


def pool_specs(ctx: ShardCtx):
    """PartitionSpec tree matching ``init_pool``: kv heads over 'model',
    the page axis replicated (pages are slot-agnostic, any sequence's
    next page can land anywhere in the pool)."""
    kv = P(None, None, ctx.model_axis, None, None)
    return {"layers": {"k": kv, "v": kv}}


def write_prompt(pool, prefill_cache, pages):
    """Scatter a single-sequence prefill KV cache into freshly-allocated
    pages.  pool leaf: (L, P, kvl, ps, hd); prefill leaf: (L, 1, kvl, t,
    hd) with t <= len(pages) * ps; pages: (nb,) page ids in logical-block
    order.  The tail of the last page stays zero (masked as invalid)."""
    def leaf(pl, kv):
        n_layers, _, kvl, ps, hd = pl.shape
        t = kv.shape[3]
        nb = pages.shape[0]
        kv = jnp.pad(kv[:, 0], ((0, 0), (0, 0), (0, nb * ps - t), (0, 0)))
        tiles = kv.reshape(n_layers, kvl, nb, ps, hd).transpose(0, 2, 1, 3, 4)
        return pl.at[:, pages].set(tiles.astype(pl.dtype))
    return jax.tree.map(leaf, pool, prefill_cache)


def write_prompts(pool, prefill_cache, page_tables, lengths):
    """Scatter a BATCHED prefill KV cache into each row's pages — the
    one-launch form of ``write_prompt`` the batched-prefill engine path
    uses.  pool leaf: (L, P, kvl, ps, hd); prefill leaf: (L, b, kvl, t,
    hd) with t a multiple of ps (the engine's page-aligned length
    bucket); page_tables: (b, t // ps) page ids in logical-block order,
    null page 0 for blocks beyond a row's allocation; lengths: (b,)
    valid tokens per row (0 = inactive pad row).

    Positions >= a row's length are zeroed before the scatter (pad-token
    KV never lands in the pool — the tail of the last page stays zero,
    matching write_prompt), and the null page — hit by every pad row and
    unallocated block — is re-zeroed afterwards, so its contents stay
    the all-zero invariant the tests pin down."""
    def leaf(pl, kv):
        n_layers, _, kvl, ps, hd = pl.shape
        b, t = kv.shape[1], kv.shape[3]
        nb = t // ps
        valid = jnp.arange(t)[None, :] < lengths[:, None]          # (b, t)
        kv = jnp.where(valid[None, :, None, :, None], kv, 0)
        tiles = kv.reshape(n_layers, b, kvl, nb, ps, hd)
        tiles = tiles.transpose(0, 1, 3, 2, 4, 5).reshape(
            n_layers, b * nb, kvl, ps, hd)
        out = pl.at[:, page_tables.reshape(b * nb)].set(
            tiles.astype(pl.dtype))
        return out.at[:, NULL_PAGE].set(0)
    return jax.tree.map(leaf, pool, prefill_cache)
