"""Shard_map-native layer primitives (Megatron-JAX style).

Everything in this module runs INSIDE shard_map: parameters arrive as local
shards, activations are replicated across the 'model' axis, and tensor
parallelism is expressed with explicit lax collectives:

  column-parallel in-projections : no communication
  row-parallel out-projections   : lax.psum over 'model'
  vocab-sharded embedding/logits : lax.psum over 'model'

The blocked-attention implementations here are the pure-jnp twins of the
Pallas kernels in repro.kernels (same math, scan-over-KV-tiles online
softmax) so that CPU dry-runs lower to compact HLO with O(s*d) memory.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static sharding context threaded through the model code."""
    tp: int = 1                   # size of 'model' axis
    dp: int = 1                   # size of 'data' axis
    pods: int = 1                 # size of 'pod' axis (1 = single pod)
    model_axis: str = "model"
    data_axis: str = "data"
    pod_axis: str = "pod"
    fsdp: bool = False            # params sharded over data axis
    seq_shard_cache: bool = False  # decode KV cache sharded over data axis
    seq_parallel: bool = False    # residual stream seq-sharded over model
    remat_groups: int = 0         # nested-remat group count (0 = flat scan)

    @property
    def dp_axes(self) -> tuple:
        return (self.pod_axis, self.data_axis) if self.pods > 1 else (self.data_axis,)


def tp_index(ctx: ShardCtx):
    return lax.axis_index(ctx.model_axis)


def gather_fsdp(ctx: ShardCtx, w: jnp.ndarray, axis: int) -> jnp.ndarray:
    """All-gather an FSDP-sharded weight along ``axis`` (no-op w/o fsdp).
    Backward is automatically psum_scatter (ZeRO-3 gradient flow)."""
    if not ctx.fsdp:
        return w
    return lax.all_gather(w, ctx.data_axis, axis=axis, tiled=True)


def sp_gather(ctx: ShardCtx, h: jnp.ndarray) -> jnp.ndarray:
    """Sequence-parallel entry: all-gather the seq-sharded activations to
    full sequence before TP matmuls (Megatron-SP). No-op without SP."""
    if not ctx.seq_parallel:
        return h
    return lax.all_gather(h, ctx.model_axis, axis=1, tiled=True)


def sp_out(ctx: ShardCtx, y: jnp.ndarray) -> jnp.ndarray:
    """Row-parallel exit: with SP, reduce-scatter the block output back to
    the seq-sharded residual layout (same wire bytes as the psum it
    replaces, 1/tp the activation memory); otherwise psum."""
    if ctx.seq_parallel:
        return lax.psum_scatter(y, ctx.model_axis, scatter_dimension=1,
                                tiled=True)
    return lax.psum(y, ctx.model_axis)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., t, h, hd), pos: (t,) or (b, t)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    ang = pos[..., None].astype(jnp.float32) * freqs        # (..., t, hd/2)
    ang = ang[..., None, :]                                  # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------- embedding & loss -------------------------

def embed_lookup(ctx: ShardCtx, emb: jnp.ndarray, ids: jnp.ndarray,
                 vocab: int) -> jnp.ndarray:
    """Vocab-sharded embedding lookup. emb: (V_local, d) local shard."""
    v_local = emb.shape[0]
    lo = tp_index(ctx) * v_local
    local = jnp.clip(ids - lo, 0, v_local - 1)
    x = jnp.take(emb, local, axis=0)
    mask = ((ids >= lo) & (ids < lo + v_local))[..., None]
    x = jnp.where(mask, x, 0).astype(emb.dtype)
    return sp_out(ctx, x)


def lm_loss(ctx: ShardCtx, x: jnp.ndarray, head: jnp.ndarray,
            targets: jnp.ndarray, mask: jnp.ndarray | None = None,
            chunk: int = 1024):
    """Vocab-sharded cross-entropy. x: (b, t, d), head: (d, V_local),
    targets: (b, t) global token ids. Returns mean NLL over local tokens.

    Long sequences are processed in seq chunks under jax.checkpoint so the
    (b, t, V_local) fp32 logits are never live all at once (§Perf:
    memory term)."""
    t = x.shape[1]
    if t > chunk:
        pad = (-t) % chunk
        if mask is None:
            mask = jnp.ones(targets.shape, jnp.float32)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nc = x.shape[1] // chunk
        xs = x.reshape(x.shape[0], nc, chunk, -1).transpose(1, 0, 2, 3)
        ts = targets.reshape(targets.shape[0], nc, chunk).transpose(1, 0, 2)
        ms = mask.reshape(mask.shape[0], nc, chunk).transpose(1, 0, 2)

        @jax.checkpoint
        def body(acc, ins):
            xc, tc, mc = ins
            nll_mean = lm_loss(ctx, xc, head, tc, mask=mc, chunk=10 ** 9)
            return (acc[0] + nll_mean * jnp.sum(mc), acc[1] + jnp.sum(mc)), None

        (tot, cnt), _ = lax.scan(body, (0.0, 0.0), (xs, ts, ms))
        return tot / jnp.maximum(cnt, 1.0)
    v_local = head.shape[-1]
    logits = (x @ head).astype(jnp.float32)                 # (b, t, Vl)
    # stability shift only — no gradient needs to flow through the max,
    # so stop_gradient BEFORE pmax (pmax has no differentiation rule)
    m = lax.pmax(jnp.max(lax.stop_gradient(logits), axis=-1),
                 ctx.model_axis)                             # (b, t)
    lse = jnp.log(lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                           ctx.model_axis)) + m
    lo = tp_index(ctx) * v_local
    local_t = jnp.clip(targets - lo, 0, v_local - 1)
    tgt_logit = jnp.take_along_axis(logits, local_t[..., None], axis=-1)[..., 0]
    in_shard = (targets >= lo) & (targets < lo + v_local)
    tgt_logit = lax.psum(jnp.where(in_shard, tgt_logit, 0.0), ctx.model_axis)
    nll = lse - tgt_logit
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------ blocked attention -------------------------

NEG_INF = -1e30


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, blk_q: int = 1024,
                      blk_kv: int = 512) -> jnp.ndarray:
    """Online-softmax attention, scan over Q tiles x KV tiles (jnp twin of
    the Pallas flash kernel; O(blk_q*blk_kv) score memory).

    q: (b, h, sq, hd), k/v: (b, hkv, skv, hd). GQA-aware."""
    b, h, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]                       # MLA: v head dim may differ
    rep = h // hkv
    scale = hd ** -0.5
    blk_q = min(blk_q, sq)
    blk_kv = min(blk_kv, skv)
    pad_q = (-sq) % blk_q
    pad_kv = (-skv) % blk_kv
    qf = q.astype(jnp.float32).reshape(b, hkv, rep, sq, hd) * scale
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    nq, nkv = qf.shape[3] // blk_q, kf.shape[2] // blk_kv
    # (nq, b, g, r, blk_q, hd)
    qt = qf.reshape(b, hkv, rep, nq, blk_q, hd).transpose(3, 0, 1, 2, 4, 5)
    kt = kf.reshape(b, hkv, nkv, blk_kv, hd).transpose(2, 0, 1, 3, 4)
    vt = vf.reshape(b, hkv, nkv, blk_kv, hdv).transpose(2, 0, 1, 3, 4)
    shift = skv - sq  # causal alignment at the sequence end

    def q_tile(_, qin):
        qb, qi = qin
        rows = qi * blk_q + jnp.arange(blk_q)

        @jax.checkpoint
        def kv_tile(carry, kin):
            m_prev, l_prev, acc = carry
            kb, vb, ki = kin
            cols = ki * blk_kv + jnp.arange(blk_kv)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb, kb)
            keep = cols[None, :] < skv
            if causal:
                keep = keep & (cols[None, :] <= rows[:, None] + shift)
            s = jnp.where(keep, s, NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_cur[..., None])
            alpha = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bgrqk,bgkd->bgrqd", p, vb)
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((b, hkv, rep, blk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, blk_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, blk_q, hdv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_tile, (m0, l0, a0),
                                  (kt, vt, jnp.arange(nkv)))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, out = lax.scan(jax.checkpoint(q_tile), None, (qt, jnp.arange(nq)))
    # (nq, b, g, r, blk_q, hd) -> (b, h, sq, hd)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, rep, nq * blk_q, hdv)
    out = out[:, :, :, :sq].reshape(b, h, sq, hdv)
    return out.astype(q.dtype)


def decode_attention(ctx: ShardCtx, q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """One-token attention over a (possibly sequence-sharded) KV cache.

    q: (b, h, 1, hd); k_cache/v_cache: (b, hkv, S_local, hd); pos: ()
    global number of valid cache entries, or (b,) per-slot counts (the
    continuous-batching engine packs sequences of different lengths into
    one batch). When ctx.seq_shard_cache, the cache's S dim is sharded
    over the data axis and partial softmax stats are merged across it
    (flash-decode)."""
    b, h, _, hd = q.shape
    hkv, s_local = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, rep, hd) * scale
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bgrd,bgkd->bgrk", qf, kf)
    if ctx.seq_shard_cache:
        offset = lax.axis_index(ctx.data_axis) * s_local
    else:
        offset = 0
    pos = jnp.asarray(pos)
    idx = offset + jnp.arange(s_local)
    if pos.ndim:
        valid = (idx[None, :] < pos[:, None])[:, None, None, :]
    else:
        valid = (idx < pos)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    if ctx.seq_shard_cache:
        m = lax.pmax(m, ctx.data_axis)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bgrk,bgkd->bgrd", p, v_cache.astype(jnp.float32))
    if ctx.seq_shard_cache:
        l = lax.psum(l, ctx.data_axis)
        acc = lax.psum(acc, ctx.data_axis)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, 1, hd).astype(q.dtype)


# ------------------------------- MLP --------------------------------

def swiglu_mlp(ctx: ShardCtx, x: jnp.ndarray, w_gate, w_up, w_down):
    """Column/row-parallel SwiGLU. w_gate/w_up: (d, ff_local) local shards,
    w_down: (ff_local, d). Ends with psum over the model axis."""
    g = x @ gather_fsdp(ctx, w_gate, 0)
    u = x @ gather_fsdp(ctx, w_up, 0)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = h @ gather_fsdp(ctx, w_down, 1)
    return lax.psum(out, ctx.model_axis)


def update_cache(cache: jnp.ndarray, new: jnp.ndarray, pos,
                 ctx: ShardCtx) -> jnp.ndarray:
    """Write one decode step's K or V into the cache at global position
    ``pos``. cache: (b, hkv, S_local, hd), new: (b, hkv, 1, hd)."""
    if ctx.seq_shard_cache:
        s_local = cache.shape[2]
        owner = pos // s_local
        local_pos = pos - owner * s_local
        updated = lax.dynamic_update_slice(
            cache, new.astype(cache.dtype), (0, 0, local_pos, 0))
        mine = lax.axis_index(ctx.data_axis) == owner
        return jnp.where(mine, updated, cache)
    return lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                    (0, 0, pos, 0))


# -------------------------- paged KV cache ---------------------------

def paged_update_cache(pool: jnp.ndarray, new: jnp.ndarray, page_ids,
                       offsets) -> jnp.ndarray:
    """Write one decode step's K or V for a packed slot batch into a paged
    pool.  pool: (P, hkv, page, hd) physical pages shared by every slot;
    new: (b, hkv, 1, hd); page_ids/offsets: (b,) each slot's target page
    and in-page offset.  Inactive slot rows point at the reserved null
    page 0, whose contents are never read as valid."""
    return pool.at[page_ids, :, offsets, :].set(
        new[:, :, 0, :].astype(pool.dtype))


def paged_gather(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize each slot's pages as a contiguous (b, hkv, nb*page, hd)
    KV view.  pool: (P, hkv, page, hd); page_table: (b, nb) page ids in
    logical-block order.  Table entries beyond a slot's allocation hit the
    null page and are masked out by decode_attention's validity test."""
    b, nb = page_table.shape
    _, hkv, ps, hd = pool.shape
    pages = jnp.take(pool, page_table, axis=0)       # (b, nb, hkv, ps, hd)
    return pages.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nb * ps, hd)
