"""Model assembly: parameter specs/init, forward pass, and the train /
prefill / decode step functions, all shard_map-native.

Parameters are dicts of stacked arrays with a leading layer axis, scanned
with lax.scan + jax.checkpoint so the HLO (and compile time) is O(1) in
depth. Every leaf has a PartitionSpec in ``param_specs`` — the same tree
drives shard_map in_specs, checkpoint manifests, and the dry-run.

Sharding convention (axes: pod, data, model):
  column-parallel weights  (d, f)  -> P(None, fsdp?, 'model')
  row-parallel weights     (f, d)  -> P(None, 'model', fsdp?)
  embeddings / lm head              -> vocab over 'model', d over fsdp?
  small norms / biases              -> replicated
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import blocks
from .config import ModelConfig
from .layers import (ShardCtx, embed_lookup, gather_fsdp, lm_loss, rmsnorm,
                     sp_gather)


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class ArchDims:
    """All padded / per-shard dimensions derived from (cfg, ctx)."""
    h_pad: int      # query heads padded to multiple of tp
    kv_pad: int     # kv heads padded/replicated to multiple of tp
    v_pad: int      # vocab padded to multiple of tp
    ff_pad: int
    d_model: int

    @classmethod
    def build(cls, cfg: ModelConfig, ctx: ShardCtx):
        return cls(
            h_pad=pad_to(cfg.n_heads, ctx.tp),
            kv_pad=max(cfg.n_kv_heads, ctx.tp) if cfg.n_kv_heads < ctx.tp
            else pad_to(cfg.n_kv_heads, ctx.tp),
            v_pad=pad_to(cfg.vocab, ctx.tp),
            ff_pad=pad_to(max(cfg.d_ff, 1), ctx.tp),
            d_model=cfg.d_model,
        )


# ====================== parameter specs and init ======================

def _fsdp(ctx):  # helper: the axis name used for FSDP or None
    return ctx.data_axis if ctx.fsdp else None


def attn_param_specs(cfg, ctx, dims):
    fa = _fsdp(ctx)
    hd = cfg.hd
    spec = {
        "norm": P(None, None),
        "wq": P(None, fa, ctx.model_axis),
        "wk": P(None, fa, ctx.model_axis),
        "wv": P(None, fa, ctx.model_axis),
        "wo": P(None, ctx.model_axis, fa),
    }
    shapes = {
        "norm": (cfg.d_model,),
        "wq": (cfg.d_model, dims.h_pad * hd),
        "wk": (cfg.d_model, dims.kv_pad * hd),
        "wv": (cfg.d_model, dims.kv_pad * hd),
        "wo": (dims.h_pad * hd, cfg.d_model),
    }
    if cfg.qk_norm:
        spec["q_norm"] = P(None, None)
        spec["k_norm"] = P(None, None)
        shapes["q_norm"] = (hd,)
        shapes["k_norm"] = (hd,)
    return spec, shapes


def mla_param_specs(cfg, ctx, dims):
    fa = _fsdp(ctx)
    hd, rd = cfg.hd, cfg.qk_rope_dim
    spec = {
        "norm": P(None, None),
        "wq_a": P(None, fa, None),
        "q_norm": P(None, None),
        "wq_b": P(None, None, ctx.model_axis),
        "wkv_a": P(None, fa, None),
        "kv_norm": P(None, None),
        "wkv_b": P(None, None, ctx.model_axis),
        "wo": P(None, ctx.model_axis, fa),
    }
    shapes = {
        "norm": (cfg.d_model,),
        "wq_a": (cfg.d_model, cfg.q_lora_rank),
        "q_norm": (cfg.q_lora_rank,),
        "wq_b": (cfg.q_lora_rank, dims.h_pad * (hd + rd)),
        "wkv_a": (cfg.d_model, cfg.kv_lora_rank + rd),
        "kv_norm": (cfg.kv_lora_rank,),
        "wkv_b": (cfg.kv_lora_rank, dims.h_pad * 2 * hd),
        "wo": (dims.h_pad * hd, cfg.d_model),
    }
    return spec, shapes


def mlp_param_specs(cfg, ctx, dims, ff=None):
    fa = _fsdp(ctx)
    ff = ff or dims.ff_pad
    spec = {
        "mlp_norm": P(None, None),
        "w_gate": P(None, fa, ctx.model_axis),
        "w_up": P(None, fa, ctx.model_axis),
        "w_down": P(None, ctx.model_axis, fa),
    }
    shapes = {
        "mlp_norm": (cfg.d_model,),
        "w_gate": (cfg.d_model, ff),
        "w_up": (cfg.d_model, ff),
        "w_down": (ff, cfg.d_model),
    }
    return spec, shapes


def moe_param_specs(cfg, ctx, dims):
    fa = _fsdp(ctx)
    ffe = cfg.moe_d_ff
    spec = {
        "norm": P(None, None),
        "router": P(None, None, ctx.model_axis),
        "w_gate": P(None, ctx.model_axis, fa, None),
        "w_up": P(None, ctx.model_axis, fa, None),
        "w_down": P(None, ctx.model_axis, None, fa),
    }
    shapes = {
        "norm": (cfg.d_model,),
        "router": (cfg.d_model, cfg.n_experts),
        "w_gate": (cfg.n_experts, cfg.d_model, ffe),
        "w_up": (cfg.n_experts, cfg.d_model, ffe),
        "w_down": (cfg.n_experts, ffe, cfg.d_model),
    }
    if cfg.n_shared_experts:
        sh = pad_to(cfg.n_shared_experts * ffe, ctx.tp)
        spec.update({"sh_gate": P(None, fa, ctx.model_axis),
                     "sh_up": P(None, fa, ctx.model_axis),
                     "sh_down": P(None, ctx.model_axis, fa)})
        shapes.update({"sh_gate": (cfg.d_model, sh),
                       "sh_up": (cfg.d_model, sh),
                       "sh_down": (sh, cfg.d_model)})
    return spec, shapes


def mamba_param_specs(cfg, ctx, dims):
    fa = _fsdp(ctx)
    d = cfg.d_model
    di = 2 * d
    hp = 64
    nh = di // hp
    n = cfg.ssm_state
    spec = {
        "norm": P(None, None),
        "w_x": P(None, fa, ctx.model_axis),
        "w_z": P(None, fa, ctx.model_axis),
        "w_bc": P(None, fa, None),
        "w_dt": P(None, None, ctx.model_axis),
        "conv_x": P(None, None, ctx.model_axis),
        "conv_bc": P(None, None, None),
        "dt_bias": P(None, ctx.model_axis),
        "a_log": P(None, ctx.model_axis),
        "d_skip": P(None, ctx.model_axis),
        "w_out": P(None, ctx.model_axis, fa),
    }
    shapes = {
        "norm": (d,), "w_x": (d, di), "w_z": (d, di), "w_bc": (d, 2 * n),
        "w_dt": (d, nh), "conv_x": (4, di), "conv_bc": (4, 2 * n),
        "dt_bias": (nh,), "a_log": (nh,), "d_skip": (nh,),
        "w_out": (di, d),
    }
    return spec, shapes


def mlstm_param_specs(cfg, ctx, dims):
    fa = _fsdp(ctx)
    d = cfg.d_model
    di = 2 * d
    nh = dims.h_pad
    spec = {
        "norm": P(None, None),
        "w_q": P(None, fa, ctx.model_axis),
        "w_k": P(None, fa, ctx.model_axis),
        "w_v": P(None, fa, ctx.model_axis),
        "w_z": P(None, fa, ctx.model_axis),
        "w_if": P(None, None, ctx.model_axis),
        "w_out": P(None, ctx.model_axis, fa),
    }
    shapes = {
        "norm": (d,), "w_q": (d, di), "w_k": (d, di), "w_v": (d, di),
        "w_z": (d, di), "w_if": (d, 2 * nh), "w_out": (di, d),
    }
    return spec, shapes


def slstm_param_specs(cfg, ctx, dims):
    fa = _fsdp(ctx)
    d = cfg.d_model
    di = d
    nh = dims.h_pad
    hp = di // nh
    spec = {
        "norm": P(None, None),
        "w_in": P(None, fa, ctx.model_axis),
        "r": P(None, ctx.model_axis, None, None),
        "w_out": P(None, ctx.model_axis, fa),
    }
    shapes = {"norm": (d,), "w_in": (d, 4 * di), "r": (nh, hp, hp),
              "w_out": (di, d)}
    return spec, shapes


def _stacked(n_layers, spec, shapes):
    return ({k: v for k, v in spec.items()},
            {k: (n_layers,) + s for k, s in shapes.items()})


def param_specs(cfg: ModelConfig, ctx: ShardCtx):
    """Returns (specs, shapes): flat dict trees keyed by component."""
    dims = ArchDims.build(cfg, ctx)
    fa = _fsdp(ctx)
    specs = {"embed": P(ctx.model_axis, fa),
             "final_norm": P(None),
             "lm_head": P(fa, ctx.model_axis)}
    shapes = {"embed": (dims.v_pad, cfg.d_model),
              "final_norm": (cfg.d_model,),
              "lm_head": (cfg.d_model, dims.v_pad)}

    def add(prefix, n, builder, **kw):
        sp, sh = builder(cfg, ctx, dims, **kw)
        sp, sh = _stacked(n, sp, sh)
        specs[prefix] = sp
        shapes[prefix] = sh

    if cfg.ssm == "mamba2":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        n_ssm = cfg.n_layers - n_attn
        add("mamba", n_ssm, mamba_param_specs)
        if n_attn:  # shared attention block (zamba2): NOT stacked, so the
            # builders' leading layer-axis spec entry is stripped
            asp, ash = attn_param_specs(cfg, ctx, dims)
            msp, msh = mlp_param_specs(cfg, ctx, dims)
            specs["shared_attn"] = {k: P(*tuple(v)[1:])
                                    for k, v in {**asp, **msp}.items()}
            shapes["shared_attn"] = {**ash, **msh}
    elif cfg.ssm == "xlstm":
        n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
        n_m = cfg.n_layers - n_s
        add("mlstm", n_m, mlstm_param_specs)
        if n_s:
            add("slstm", n_s, slstm_param_specs)
    elif cfg.enc_dec:
        esp, esh = attn_param_specs(cfg, ctx, dims)
        emsp, emsh = mlp_param_specs(cfg, ctx, dims)
        specs["encoder"] = _stacked(cfg.n_enc_layers, {**esp, **emsp},
                                    {**esh, **emsh})[0]
        shapes["encoder"] = _stacked(cfg.n_enc_layers, {**esp, **emsp},
                                     {**esh, **emsh})[1]
        dsp, dsh = attn_param_specs(cfg, ctx, dims)
        xsp, xsh = attn_param_specs(cfg, ctx, dims)
        dmsp, dmsh = mlp_param_specs(cfg, ctx, dims)
        dec_sp = {**dsp, **{f"x_{k}": v for k, v in xsp.items()}, **dmsp}
        dec_sh = {**dsh, **{f"x_{k}": v for k, v in xsh.items()}, **dmsh}
        specs["decoder"] = _stacked(cfg.n_layers, dec_sp, dec_sh)[0]
        shapes["decoder"] = _stacked(cfg.n_layers, dec_sp, dec_sh)[1]
    elif cfg.moe:
        attn_builder = mla_param_specs if cfg.mla else attn_param_specs
        nd = cfg.first_dense_layers
        nm = cfg.n_layers - nd
        asp, ash = attn_builder(cfg, ctx, dims)
        msp, msh = moe_param_specs(cfg, ctx, dims)
        specs["moe_layers"] = _stacked(nm, {**asp, **msp}, {**ash, **msh})[0]
        shapes["moe_layers"] = _stacked(nm, {**asp, **msp}, {**ash, **msh})[1]
        if nd:
            dsp, dsh = attn_builder(cfg, ctx, dims)
            mlsp, mlsh = mlp_param_specs(cfg, ctx, dims)
            specs["dense_layers"] = _stacked(nd, {**dsp, **mlsp},
                                             {**dsh, **mlsh})[0]
            shapes["dense_layers"] = _stacked(nd, {**dsp, **mlsp},
                                              {**dsh, **mlsh})[1]
        if cfg.mtp:  # multi-token-prediction block (training only)
            tsp, tsh = attn_builder(cfg, ctx, dims)
            tmsp, tmsh = mlp_param_specs(cfg, ctx, dims)
            specs["mtp"] = _stacked(1, {**tsp, **tmsp}, {**tsh, **tmsh})[0]
            shapes["mtp"] = _stacked(1, {**tsp, **tmsp}, {**tsh, **tmsh})[1]
    else:  # dense transformer
        asp, ash = attn_param_specs(cfg, ctx, dims)
        msp, msh = mlp_param_specs(cfg, ctx, dims)
        specs["layers"] = _stacked(cfg.n_layers, {**asp, **msp},
                                   {**ash, **msh})[0]
        shapes["layers"] = _stacked(cfg.n_layers, {**asp, **msp},
                                    {**ash, **msh})[1]
    return specs, shapes


def param_shape_dtype(cfg: ModelConfig, ctx: ShardCtx):
    """ShapeDtypeStruct tree for the dry-run (no allocation)."""
    _, shapes = param_specs(cfg, ctx)
    dt = _dt(cfg)

    def to_sds(tree):
        if isinstance(tree, dict):
            return {k: to_sds(v) for k, v in tree.items()}
        return jax.ShapeDtypeStruct(tree, dt)
    return to_sds(shapes)


def flat_specs(cfg: ModelConfig, ctx: ShardCtx):
    specs, _ = param_specs(cfg, ctx)
    return specs


def init_params(cfg: ModelConfig, ctx: ShardCtx, key):
    """Materialize (global) parameters — smoke tests / real runs only."""
    _, shapes = param_specs(cfg, ctx)
    dt = _dt(cfg)
    leaves, treedef = jax.tree.flatten(shapes,
                                       is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, shp in zip(keys, leaves):
        fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
        if len(shp) == 1 or shp[-1] == 1:
            out.append(jnp.ones(shp, dt))
        else:
            out.append((jax.random.normal(k, shp, jnp.float32)
                        * (0.02 if fan_in > 8 else 0.5)).astype(dt))
    params = jax.tree.unflatten(treedef, out)
    return _fix_special_inits(cfg, params)


def _fix_special_inits(cfg, params):
    """Norms -> 1, ssm dt_bias/a_log sensible ranges, zero-pad the padded
    query heads' wq/wo so they contribute nothing."""
    def fix(prefix, p):
        upd = dict(p)
        for k in p:
            if k.endswith("norm") or k in ("final_norm",):
                upd[k] = jnp.ones_like(p[k])
        if "a_log" in p:
            upd["a_log"] = jnp.zeros_like(p["a_log"])       # A = -1
            upd["dt_bias"] = jnp.full_like(p["dt_bias"], 0.5)
            upd["d_skip"] = jnp.ones_like(p["d_skip"])
        return upd

    out = {}
    for key, val in params.items():
        if isinstance(val, dict):
            out[key] = fix(key, val)
        else:
            out[key] = jnp.ones_like(val) if key == "final_norm" else val
    return out


# ============================== forward ==============================

def scan_layers(body, carry, stacked, ctx: ShardCtx, remat: bool = True):
    """lax.scan over stacked layer params with optional two-level
    (grouped) remat: the outer scan checkpoints group boundaries, the inner
    scan checkpoints layer boundaries, so live residuals drop from O(L) to
    O(G + L/G) (§Perf hillclimb: memory term)."""
    b = jax.checkpoint(body) if remat else body
    leaves = jax.tree.leaves(stacked)
    n = leaves[0].shape[0]
    g = ctx.remat_groups
    if remat and g > 1 and n % g == 0 and n // g > 1:
        grouped = jax.tree.map(
            lambda a: a.reshape(g, n // g, *a.shape[1:]), stacked)

        @jax.checkpoint
        def group(carry, p):
            carry, _ = lax.scan(b, carry, p)
            return carry, None

        carry, _ = lax.scan(group, carry, grouped)
        return carry, None
    return lax.scan(b, carry, stacked)

def _attn_mlp_layer(ctx, cfg, p, x, pos, cache=None, cache_pos=None,
                    kv_ext=None, causal=True, prefix=""):
    """One pre-norm transformer layer (attention + SwiGLU MLP)."""
    attn_p = {k[len(prefix):]: v for k, v in p.items()} if prefix else p
    a, new_cache = blocks.gqa_attention(ctx, cfg, attn_p, x, pos, cache,
                                        cache_pos, kv_ext, causal)
    x = x + a
    h = rmsnorm(x, p["mlp_norm"])
    x = x + blocks.swiglu_mlp(ctx, h, p["w_gate"], p["w_up"], p["w_down"])
    return x, new_cache


def _mla_moe_layer(ctx, cfg, p, x, pos, cache=None, cache_pos=None,
                   dense_mlp=False):
    if cfg.mla:
        a, new_cache = blocks.mla_attention(ctx, cfg, p, x, pos, cache,
                                            cache_pos)
    else:
        a, new_cache = blocks.gqa_attention(ctx, cfg, p, x, pos, cache,
                                            cache_pos)
    x = x + a
    if dense_mlp:
        h = rmsnorm(x, p["mlp_norm"])
        x = x + blocks.swiglu_mlp(ctx, h, p["w_gate"], p["w_up"], p["w_down"])
        return x, new_cache, 0.0
    y, aux = blocks.moe_block(ctx, cfg, p, x)
    return x + y, new_cache, aux


def forward_lm(cfg: ModelConfig, ctx: ShardCtx, params, tokens,
               enc_frames=None, remat: bool = True):
    """Training/prefill forward. tokens: (b, t) local batch shard.
    Returns (hidden, aux_loss)."""
    b, t = tokens.shape
    pos = jnp.arange(t)
    emb = gather_fsdp(ctx, params["embed"], 1)
    x = embed_lookup(ctx, emb, tokens, cfg.vocab)
    aux_total = 0.0

    def ckpt(f):
        return jax.checkpoint(f) if remat else f

    if cfg.ssm == "mamba2":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        n_ssm = params["mamba"]["norm"].shape[0]

        @ckpt
        def mamba_body(x, p):
            y, _ = blocks.mamba2_block(ctx, cfg, p, x)
            return x + y, None

        if n_attn:
            per = n_ssm // n_attn
            grouped = n_attn * per
            gp = jax.tree.map(
                lambda a: a[:grouped].reshape(n_attn, per, *a.shape[1:]),
                params["mamba"])
            shared = params["shared_attn"]

            @ckpt
            def group_body(x, p):
                x, _ = lax.scan(mamba_body, x, p)
                x, _ = _attn_mlp_layer(ctx, cfg, shared, x, pos)
                return x, None

            x, _ = lax.scan(group_body, x, gp)
            tail = jax.tree.map(lambda a: a[grouped:], params["mamba"])
            if n_ssm - grouped:
                x, _ = lax.scan(mamba_body, x, tail)
        else:
            x, _ = lax.scan(mamba_body, x, params["mamba"])
    elif cfg.ssm == "xlstm":
        n_s = params.get("slstm", {"norm": jnp.zeros((0,))})["norm"].shape[0]
        n_m = params["mlstm"]["norm"].shape[0]

        @ckpt
        def mlstm_body(x, p):
            y, _ = blocks.mlstm_block(ctx, cfg, p, x)
            return x + y, None

        if n_s:
            per = n_m // n_s
            gp = jax.tree.map(
                lambda a: a[:n_s * per].reshape(n_s, per, *a.shape[1:]),
                params["mlstm"])

            @ckpt
            def group_body(x, ps):
                pm, psl = ps
                x, _ = lax.scan(mlstm_body, x, pm)
                y, _ = blocks.slstm_block(ctx, cfg, psl, x)
                return x + y, None

            x, _ = lax.scan(group_body, x, (gp, params["slstm"]))
            tail = jax.tree.map(lambda a: a[n_s * per:], params["mlstm"])
            if n_m - n_s * per:
                x, _ = lax.scan(mlstm_body, x, tail)
        else:
            x, _ = lax.scan(mlstm_body, x, params["mlstm"])
    elif cfg.enc_dec:
        assert enc_frames is not None
        e = enc_frames.astype(x.dtype)
        epos = jnp.arange(e.shape[1])

        @ckpt
        def enc_body(e, p):
            e, _ = _attn_mlp_layer(ctx, cfg, p, e, epos, causal=False)
            return e, None

        e, _ = lax.scan(enc_body, e, params["encoder"])

        @ckpt
        def dec_body(x, p):
            x, _ = _attn_mlp_layer(ctx, cfg, p, x, pos)
            xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
            hl = xp["wq"].shape[-1] // cfg.hd
            kvl = xp["wk"].shape[-1] // cfg.hd
            be, te = e.shape[0], e.shape[1]
            k = (e @ xp["wk"]).reshape(be, te, kvl, cfg.hd).transpose(0, 2, 1, 3)
            v = (e @ xp["wv"]).reshape(be, te, kvl, cfg.hd).transpose(0, 2, 1, 3)
            a, _ = blocks.gqa_attention(ctx, cfg, xp, x, None,
                                        kv_ext=(k, v), causal=False)
            return x + a, None

        x, _ = lax.scan(dec_body, x, params["decoder"])
    elif cfg.moe:
        if cfg.first_dense_layers:
            @ckpt
            def dense_body(x, p):
                x, _, _ = _mla_moe_layer(ctx, cfg, p, x, pos, dense_mlp=True)
                return x, None
            x, _ = lax.scan(dense_body, x, params["dense_layers"])

        def moe_body(carry, p):
            x, aux = carry
            x, _, a = _mla_moe_layer(ctx, cfg, p, x, pos)
            return (x, aux + a), None

        (x, aux_total), _ = scan_layers(moe_body, (x, 0.0),
                                        params["moe_layers"], ctx, remat)
    else:
        def body(x, p):
            x, _ = _attn_mlp_layer(ctx, cfg, p, x, pos)
            return x, None

        x, _ = scan_layers(body, x, params["layers"], ctx, remat)
    return x, aux_total


def loss_fn(cfg: ModelConfig, ctx: ShardCtx, params, batch,
            remat: bool = True):
    """Next-token NLL (+ MoE aux + optional MTP loss)."""
    tokens = batch["tokens"]
    enc = batch.get("enc_frames")
    tin = tokens[:, :-1]
    t_real = tin.shape[1]
    if ctx.seq_parallel:   # SP shards the seq dim: pad to a tp multiple
        pad = (-t_real) % ctx.tp
        if pad:
            tin = jnp.pad(tin, ((0, 0), (0, pad)))
    x, aux = forward_lm(cfg, ctx, params, tin, enc, remat)
    x = sp_gather(ctx, x)   # back to full sequence for the vocab-sharded loss
    x = x[:, :t_real]
    h = rmsnorm(x, params["final_norm"])
    head = gather_fsdp(ctx, params["lm_head"], 0)
    loss = lm_loss(ctx, h, head, tokens[:, 1:])
    if cfg.mtp:
        pos = jnp.arange(x.shape[1])
        p1 = jax.tree.map(lambda a: a[0], params["mtp"])
        # x is already gathered to full sequence here — run the MTP block
        # with SP disabled so it does not re-gather
        ctx_mtp = dataclasses.replace(ctx, seq_parallel=False)
        x2, _, _ = _mla_moe_layer(ctx_mtp, cfg, p1, x, pos, dense_mlp=True)
        h2 = rmsnorm(x2[:, :-1], params["final_norm"])
        loss = loss + 0.3 * lm_loss(ctx, h2, head, tokens[:, 2:])
    return loss + 0.01 * aux, {"nll": loss}


# =========================== serving paths ===========================

def init_cache(cfg: ModelConfig, ctx: ShardCtx, batch_local: int,
               max_seq: int):
    """Allocate the decode cache (local shards). Layout depends on family."""
    dims = ArchDims.build(cfg, ctx)
    dt = _dt(cfg)
    kvl = dims.kv_pad // ctx.tp
    hl = dims.h_pad // ctx.tp
    s_local = max_seq // ctx.dp if ctx.seq_shard_cache else max_seq
    b = batch_local

    def kv(n):
        return {"k": jnp.zeros((n, b, kvl, s_local, cfg.hd), dt),
                "v": jnp.zeros((n, b, kvl, s_local, cfg.hd), dt)}

    if cfg.ssm == "mamba2":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        n_ssm = cfg.n_layers - n_attn
        di_l = 2 * cfg.d_model // ctx.tp
        nh_l = di_l // 64
        cache = {"mamba": {
            "ssm": jnp.zeros((n_ssm, b, nh_l, 64, cfg.ssm_state), jnp.float32),
            "conv_x": jnp.zeros((n_ssm, b, 3, di_l), jnp.float32),
            "conv_bc": jnp.zeros((n_ssm, b, 3, 2 * cfg.ssm_state), jnp.float32),
        }}
        if n_attn:
            cache["attn"] = kv(n_attn)
        return cache
    if cfg.ssm == "xlstm":
        n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
        n_m = cfg.n_layers - n_s
        di_l = 2 * cfg.d_model // ctx.tp
        nh_l = dims.h_pad // ctx.tp
        hp = di_l // nh_l
        cache = {"mlstm": {"c": jnp.zeros((n_m, b, nh_l, hp, hp), jnp.float32),
                           "n": jnp.zeros((n_m, b, nh_l, hp), jnp.float32)}}
        if n_s:
            hps = (cfg.d_model // ctx.tp) // nh_l
            z = jnp.zeros((n_s, b, nh_l, hps), jnp.float32)
            cache["slstm"] = {"h": z, "c": z, "n": z, "m": z - 30.0}
        return cache
    if cfg.enc_dec:
        return {"self": kv(cfg.n_layers),
                "cross": kv(cfg.n_layers),  # filled at prefill from encoder
                }
    if cfg.moe and cfg.mla:
        nm = cfg.n_layers - cfg.first_dense_layers
        def mla(n):
            return {"ckv": jnp.zeros((n, b, s_local, cfg.kv_lora_rank), jnp.int8),
                    "scale": jnp.zeros((n, b, s_local, 1), jnp.float32),
                    "krope": jnp.zeros((n, b, s_local, cfg.qk_rope_dim), dt)}
        cache = {"moe": mla(nm)}
        if cfg.first_dense_layers:
            cache["dense"] = mla(cfg.first_dense_layers)
        return cache
    if cfg.moe:
        nm = cfg.n_layers - cfg.first_dense_layers
        cache = {"moe": kv(nm)}
        if cfg.first_dense_layers:
            cache["dense"] = kv(cfg.first_dense_layers)
        return cache
    return {"layers": kv(cfg.n_layers)}


def decode_step(cfg: ModelConfig, ctx: ShardCtx, params, cache, token,
                pos, enc_frames=None):
    """One serving step: token (b, 1) -> logits (b, V_local), new cache.
    pos: scalar int32, number of tokens already in the cache."""
    b = token.shape[0]
    x = embed_lookup(ctx, gather_fsdp(ctx, params["embed"], 1), token,
                     cfg.vocab)
    rpos = pos[None] if pos.ndim == 0 else pos
    pos_arr = jnp.full((1,), 0) + pos

    if cfg.ssm == "mamba2":
        def mamba_body(x, pc):
            p, c = pc
            y, ns = blocks.mamba2_block(ctx, cfg, p, x, state=c)
            return x + y, ns
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        n_ssm = params["mamba"]["norm"].shape[0]
        if n_attn:
            per = n_ssm // n_attn
            grouped = n_attn * per
            gp = jax.tree.map(
                lambda a: a[:grouped].reshape(n_attn, per, *a.shape[1:]),
                params["mamba"])
            gc = jax.tree.map(
                lambda a: a[:grouped].reshape(n_attn, per, *a.shape[1:]),
                cache["mamba"])
            shared = params["shared_attn"]

            def group_body(x, pcs):
                p, c, ac = pcs
                x, nc = lax.scan(mamba_body, x, (p, c))
                a, nac = blocks.gqa_attention(ctx, cfg, shared, x, pos_arr,
                                              cache=ac, cache_pos=pos)
                x = x + a
                h = rmsnorm(x, shared["mlp_norm"])
                x = x + blocks.swiglu_mlp(ctx, h, shared["w_gate"],
                                          shared["w_up"], shared["w_down"])
                return x, (nc, nac)

            x, (ncg, nac) = lax.scan(group_body, x,
                                     (gp, gc, cache["attn"]))
            new_mamba = jax.tree.map(
                lambda a: a.reshape(grouped, *a.shape[2:]), ncg)
            tailp = jax.tree.map(lambda a: a[grouped:], params["mamba"])
            tailc = jax.tree.map(lambda a: a[grouped:], cache["mamba"])
            if n_ssm - grouped:
                x, ntail = lax.scan(mamba_body, x, (tailp, tailc))
                new_mamba = jax.tree.map(
                    lambda a, b_: jnp.concatenate([a, b_]), new_mamba, ntail)
            new_cache = {"mamba": new_mamba, "attn": nac}
        else:
            x, nc = lax.scan(mamba_body, x, (params["mamba"], cache["mamba"]))
            new_cache = {"mamba": nc}
    elif cfg.ssm == "xlstm":
        def mlstm_body(x, pc):
            p, c = pc
            y, ns = blocks.mlstm_block(ctx, cfg, p, x, state=c)
            return x + y, ns
        n_s = params.get("slstm", {"norm": jnp.zeros((0,))})["norm"].shape[0]
        n_m = params["mlstm"]["norm"].shape[0]
        if n_s:
            per = n_m // n_s
            gp = jax.tree.map(
                lambda a: a[:n_s * per].reshape(n_s, per, *a.shape[1:]),
                params["mlstm"])
            gc = jax.tree.map(
                lambda a: a[:n_s * per].reshape(n_s, per, *a.shape[1:]),
                cache["mlstm"])

            def group_body(x, pcs):
                pm, cm, psl, csl = pcs
                x, ncm = lax.scan(mlstm_body, x, (pm, cm))
                y, ncs = blocks.slstm_block(ctx, cfg, psl, x, state=csl)
                return x + y, (ncm, ncs)

            x, (ncm, ncs) = lax.scan(group_body, x,
                                     (gp, gc, params["slstm"], cache["slstm"]))
            new_m = jax.tree.map(lambda a: a.reshape(n_s * per, *a.shape[2:]),
                                 ncm)
            tailp = jax.tree.map(lambda a: a[n_s * per:], params["mlstm"])
            tailc = jax.tree.map(lambda a: a[n_s * per:], cache["mlstm"])
            if n_m - n_s * per:
                x, ntail = lax.scan(mlstm_body, x, (tailp, tailc))
                new_m = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                                     new_m, ntail)
            new_cache = {"mlstm": new_m, "slstm": ncs}
        else:
            x, ncm = lax.scan(mlstm_body, x, (params["mlstm"], cache["mlstm"]))
            new_cache = {"mlstm": ncm}
    elif cfg.enc_dec:
        def dec_body(x, pc):
            p, sc, cc = pc
            x, nsc = _attn_mlp_layer(ctx, cfg, p, x, pos_arr, cache=sc,
                                     cache_pos=pos)
            xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
            a, _ = blocks.gqa_attention(ctx, cfg, xp, x, None,
                                        kv_ext=(cc["k"], cc["v"]),
                                        causal=False)
            return x + a, nsc

        x, nsc = lax.scan(dec_body, x,
                          (params["decoder"], cache["self"], cache["cross"]))
        new_cache = {"self": nsc, "cross": cache["cross"]}
    elif cfg.moe:
        def moe_body(x, pc, dense):
            p, c = pc
            x, nc, _ = _mla_moe_layer(ctx, cfg, p, x, pos_arr, cache=c,
                                      cache_pos=pos, dense_mlp=dense)
            return x, nc
        new_cache = {}
        if cfg.first_dense_layers:
            x, nd = lax.scan(partial(moe_body, dense=True), x,
                             (params["dense_layers"], cache["dense"]))
            new_cache["dense"] = nd
        x, nm = lax.scan(partial(moe_body, dense=False), x,
                         (params["moe_layers"], cache["moe"]))
        new_cache["moe"] = nm
    else:
        def body(x, pc):
            p, c = pc
            x, nc = _attn_mlp_layer(ctx, cfg, p, x, pos_arr, cache=c,
                                    cache_pos=pos)
            return x, nc
        x, nc = lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": nc}

    h = rmsnorm(x, params["final_norm"])
    logits = (h[:, 0] @ gather_fsdp(ctx, params["lm_head"], 0)
              ).astype(jnp.float32)
    return logits, new_cache


def paged_decode_step(cfg: ModelConfig, ctx: ShardCtx, params, pool,
                      page_table, lengths, token, decode_backend="gather"):
    """One continuous-batching decode step over a paged KV pool
    (dense-attention transformer families — the serving engine's path;
    recurrent/enc-dec/MoE caches keep the contiguous decode_step).

    pool: {"layers": {"k"/"v": (L, P, hkv_local, page, hd)}} physical
    pages shared by every slot; page_table: (b, nb) per-slot page ids;
    lengths: (b,) tokens already cached per slot; token: (b, 1) pending
    tokens; decode_backend: ServeConfig.decode_backend ('gather'
    materializes pages contiguous, 'paged' attends over the pool in
    place — see blocks.gqa_decode_paged).  Returns (logits (b, V_local),
    new_pool)."""
    assert not (cfg.ssm or cfg.enc_dec or cfg.moe), \
        f"paged decode needs a dense-attention cache, got {cfg.name}"
    x = embed_lookup(ctx, gather_fsdp(ctx, params["embed"], 1), token,
                     cfg.vocab)

    def body(x, pc):
        p, kv = pc
        a, nkv = blocks.gqa_decode_paged(ctx, cfg, p, x, lengths, kv,
                                         page_table,
                                         backend=decode_backend)
        x = x + a
        h = rmsnorm(x, p["mlp_norm"])
        x = x + blocks.swiglu_mlp(ctx, h, p["w_gate"], p["w_up"], p["w_down"])
        return x, nkv

    x, nkv = lax.scan(body, x, (params["layers"], pool["layers"]))
    h = rmsnorm(x, params["final_norm"])
    logits = (h[:, 0] @ gather_fsdp(ctx, params["lm_head"], 0)
              ).astype(jnp.float32)
    return logits, {"layers": nkv}


def prefill_step(cfg: ModelConfig, ctx: ShardCtx, params, tokens,
                 enc_frames=None):
    """Inference prefill: forward over the prompt, returning last-token
    logits and the populated KV cache / recurrent states."""
    b, t = tokens.shape
    pos = jnp.arange(t)
    x = embed_lookup(ctx, gather_fsdp(ctx, params["embed"], 1), tokens,
                     cfg.vocab)

    if cfg.ssm == "mamba2":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        n_ssm = params["mamba"]["norm"].shape[0]

        def mamba_body(x, p):
            y, st = blocks.mamba2_block(ctx, cfg, p, x)
            return x + y, st

        cache = {}
        if n_attn:
            per = n_ssm // n_attn
            grouped = n_attn * per
            gp = jax.tree.map(
                lambda a: a[:grouped].reshape(n_attn, per, *a.shape[1:]),
                params["mamba"])
            shared = params["shared_attn"]

            def group_body(x, p):
                x, st = lax.scan(mamba_body, x, p)
                x, kv = _attn_mlp_layer(ctx, cfg, shared, x, pos)
                return x, (st, kv)

            x, (sts, kvs) = lax.scan(group_body, x, gp)
            sts = jax.tree.map(lambda a: a.reshape(grouped, *a.shape[2:]), sts)
            if n_ssm - grouped:
                tail = jax.tree.map(lambda a: a[grouped:], params["mamba"])
                x, st_t = lax.scan(mamba_body, x, tail)
                sts = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                                   sts, st_t)
            cache = {"mamba": sts, "attn": kvs}
        else:
            x, sts = lax.scan(mamba_body, x, params["mamba"])
            cache = {"mamba": sts}
    elif cfg.ssm == "xlstm":
        n_s = params.get("slstm", {"norm": jnp.zeros((0,))})["norm"].shape[0]
        n_m = params["mlstm"]["norm"].shape[0]

        def mlstm_body(x, p):
            y, st = blocks.mlstm_block(ctx, cfg, p, x)
            return x + y, st

        if n_s:
            per = n_m // n_s
            gp = jax.tree.map(
                lambda a: a[:n_s * per].reshape(n_s, per, *a.shape[1:]),
                params["mlstm"])
            dims = ArchDims.build(cfg, ctx)
            nh_l = dims.h_pad // ctx.tp
            hp_s = (cfg.d_model // ctx.tp) // nh_l
            z0 = jnp.zeros((x.shape[0], nh_l, hp_s), jnp.float32)
            s0 = {"h": z0, "c": z0, "n": z0, "m": z0 - 30.0}

            def group_body(x, ps):
                pm, psl = ps
                x, stm = lax.scan(mlstm_body, x, pm)
                y, sts = blocks.slstm_block(ctx, cfg, psl, x, state=s0)
                return x + y, (stm, sts)

            x, (stm, sts) = lax.scan(group_body, x, (gp, params["slstm"]))
            stm = jax.tree.map(lambda a: a.reshape(n_s * per, *a.shape[2:]),
                               stm)
            if n_m - n_s * per:
                tail = jax.tree.map(lambda a: a[n_s * per:], params["mlstm"])
                x, st_t = lax.scan(mlstm_body, x, tail)
                stm = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_]),
                                   stm, st_t)
            cache = {"mlstm": stm, "slstm": sts}
        else:
            x, stm = lax.scan(mlstm_body, x, params["mlstm"])
            cache = {"mlstm": stm}
    elif cfg.enc_dec:
        assert enc_frames is not None
        e = enc_frames.astype(x.dtype)
        epos = jnp.arange(e.shape[1])

        def enc_body(e, p):
            e, _ = _attn_mlp_layer(ctx, cfg, p, e, epos, causal=False)
            return e, None

        e, _ = lax.scan(enc_body, e, params["encoder"])

        def dec_body(x, p):
            x, kv = _attn_mlp_layer(ctx, cfg, p, x, pos)
            xp = {k[2:]: v for k, v in p.items() if k.startswith("x_")}
            kvl = xp["wk"].shape[-1] // cfg.hd
            be, te = e.shape[0], e.shape[1]
            ck = (e @ xp["wk"]).reshape(be, te, kvl, cfg.hd).transpose(0, 2, 1, 3)
            cv = (e @ xp["wv"]).reshape(be, te, kvl, cfg.hd).transpose(0, 2, 1, 3)
            a, _ = blocks.gqa_attention(ctx, cfg, xp, x, None,
                                        kv_ext=(ck, cv), causal=False)
            return x + a, (kv, {"k": ck, "v": cv})

        x, (skv, ckv) = lax.scan(dec_body, x, params["decoder"])
        cache = {"self": skv, "cross": ckv}
    elif cfg.moe:
        cache = {}
        if cfg.first_dense_layers:
            def dense_body(x, p):
                x, kv, _ = _mla_moe_layer(ctx, cfg, p, x, pos, dense_mlp=True)
                return x, kv
            x, dkv = lax.scan(dense_body, x, params["dense_layers"])
            cache["dense"] = dkv

        def moe_body(x, p):
            x, kv, _ = _mla_moe_layer(ctx, cfg, p, x, pos)
            return x, kv

        x, mkv = lax.scan(moe_body, x, params["moe_layers"])
        cache["moe"] = mkv
    else:
        def body(x, p):
            x, kv = _attn_mlp_layer(ctx, cfg, p, x, pos)
            return x, kv

        x, kvs = lax.scan(body, x, params["layers"])
        cache = {"layers": kvs}

    x = sp_gather(ctx, x)
    h = rmsnorm(x[:, -1:], params["final_norm"])
    logits = (h[:, 0] @ gather_fsdp(ctx, params["lm_head"], 0)
              ).astype(jnp.float32)
    return logits, cache


def batched_prefill_step(cfg: ModelConfig, ctx: ShardCtx, params, tokens,
                         lengths):
    """Serving prefill over a PACKED prompt batch (dense-attention
    families — the continuous-batching engine's path).

    tokens: (b, t) right-padded prompts; lengths: (b,) valid tokens per
    row (0 = inactive pad row, its outputs are discarded).  Right
    padding is causal-harmless: position p only attends 0..p, so every
    row's valid-prefix KV and last-position hidden state equal its solo
    ``prefill_step`` run — pad-token KV beyond ``lengths`` is masked (or
    zeroed before the page scatter, kv_pool.write_prompts) downstream.
    Returns (per-row logits at position lengths-1 (b, V_local), cache
    {"layers": {"k","v": (L, b, kvl, t, hd)}})."""
    assert not (cfg.ssm or cfg.enc_dec or cfg.moe), \
        f"batched prefill needs a dense-attention cache, got {cfg.name}"
    b, t = tokens.shape
    pos = jnp.arange(t)
    x = embed_lookup(ctx, gather_fsdp(ctx, params["embed"], 1), tokens,
                     cfg.vocab)

    def body(x, p):
        x, kv = _attn_mlp_layer(ctx, cfg, p, x, pos)
        return x, kv

    x, kvs = lax.scan(body, x, params["layers"])
    x = sp_gather(ctx, x)
    last = jnp.maximum(lengths, 1) - 1        # pad rows clamp to position 0
    h = rmsnorm(x[jnp.arange(b), last][:, None], params["final_norm"])
    logits = (h[:, 0] @ gather_fsdp(ctx, params["lm_head"], 0)
              ).astype(jnp.float32)
    return logits, {"layers": kvs}
