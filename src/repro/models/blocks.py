"""Transformer / SSM / MoE blocks, shard_map-native.

All functions take LOCAL parameter shards and activations replicated over
the 'model' axis; each block ends with exactly one lax.psum over 'model'
(Megatron row-parallel pattern). Heads are padded to a multiple of the TP
degree at init time (zero-weight pad heads: wo pad rows are zero so the
psum is unaffected); KV heads with kv < tp are replicated per shard so that
shard m holds the KV group serving its query heads.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (NEG_INF, ShardCtx, blocked_attention, decode_attention,
                     embed_lookup, gather_fsdp, paged_gather,
                     paged_update_cache, rmsnorm, rope, sp_gather, sp_out,
                     swiglu_mlp, update_cache)


def _heads_local(h: int, tp: int) -> int:
    """Query heads per shard after padding h up to a multiple of tp."""
    return max(1, -(-h // tp))


def _kv_local(kv: int, tp: int) -> int:
    """KV heads per shard (>=1; kv < tp means replication across shards)."""
    return max(1, kv // tp)


def _qk_headnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-head RMS norm (qwen3/chameleon qk_norm). x: (..., h, hd)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + 1e-6)).astype(x.dtype) * w


# ============================ GQA attention ============================

def _gqa_qkv(ctx: ShardCtx, cfg: ModelConfig, p, x, pos):
    """Shared self-attention q/k/v projection + qk-norm + RoPE.  The
    contiguous decode path and the paged continuous-batching path both go
    through this, so their per-token math stays bit-identical.  pos: (t,)
    shared positions or (b, t) per-slot positions (rope handles both)."""
    h = sp_gather(ctx, rmsnorm(x, p["norm"]))
    b, t, d = h.shape
    hl = p["wq"].shape[-1] // cfg.hd
    kvl = p["wk"].shape[-1] // cfg.hd
    q = (h @ gather_fsdp(ctx, p["wq"], 0)).reshape(b, t, hl, cfg.hd)
    k = (h @ gather_fsdp(ctx, p["wk"], 0)).reshape(b, t, kvl, cfg.hd)
    v = (h @ gather_fsdp(ctx, p["wv"], 0)).reshape(b, t, kvl, cfg.hd)
    if cfg.qk_norm:
        q = _qk_headnorm(q, p["q_norm"])
        k = _qk_headnorm(k, p["k_norm"])
    if pos is not None:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def gqa_attention(ctx: ShardCtx, cfg: ModelConfig, p, x, pos,
                  cache=None, cache_pos=None, kv_ext=None, causal=True):
    """p: layer params dict. x: (b, t, d). pos: (t,) positions for RoPE.

    cache=(k,v) enables decode mode (t == 1). kv_ext=(k,v) enables
    cross-attention (whisper decoder). Returns (out, new_cache)."""
    if kv_ext is None:
        q, k, v = _gqa_qkv(ctx, cfg, p, x, pos)
    else:
        h = sp_gather(ctx, rmsnorm(x, p["norm"]))
        hl = p["wq"].shape[-1] // cfg.hd
        q = (h @ gather_fsdp(ctx, p["wq"], 0)).reshape(
            *h.shape[:2], hl, cfg.hd)
        k, v = kv_ext
        if cfg.qk_norm:
            q = _qk_headnorm(q, p["q_norm"])
    b, t, hl = q.shape[:3]
    q = q.transpose(0, 2, 1, 3)                      # (b, hl, t, hd)
    new_cache = None
    if cache is not None and kv_ext is None:
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        kc = update_cache(cache["k"], k, cache_pos, ctx)
        vc = update_cache(cache["v"], v, cache_pos, ctx)
        new_cache = {"k": kc, "v": vc}
        attn = decode_attention(ctx, q, kc, vc, cache_pos + 1)
    else:
        if kv_ext is None:
            k = k.transpose(0, 2, 1, 3)
            v = v.transpose(0, 2, 1, 3)
            new_cache = {"k": k, "v": v}   # collected by prefill, DCE'd in train
        attn = blocked_attention(q, k, v, causal=causal)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, t, hl * cfg.hd)
    out = attn @ gather_fsdp(ctx, p["wo"], 1)
    return sp_out(ctx, out), new_cache


def gqa_decode_paged(ctx: ShardCtx, cfg: ModelConfig, p, x, lengths,
                     pool_kv, page_table, backend: str = "gather"):
    """One paged decode step of GQA self-attention over a packed slot
    batch (continuous batching).  x: (b, 1, d) each slot's pending token;
    lengths: (b,) tokens already cached per slot (the new token's
    position); pool_kv: {"k","v"} physical page pools (P, hkv_local,
    page, hd); page_table: (b, nb) per-slot page ids.  Returns
    (out, new_pool_kv) — the same per-token math as the contiguous
    gqa_attention decode branch, so outputs match it bit-exactly.

    ``backend`` is ServeConfig.decode_backend: 'gather' materializes each
    slot's pages contiguous (paged_gather) before decode_attention;
    'paged' attends over the pool in place through the Pallas kernel
    (kernels.paged_attention) where it compiles (TPU, or forced in
    tests) and keeps the gather path as the bit-exact XLA fallback."""
    from ..kernels import paged_attention as paged_kernel
    ps = pool_kv["k"].shape[2]
    q, k, v = _gqa_qkv(ctx, cfg, p, x, lengths[:, None])
    q = q.transpose(0, 2, 1, 3)                      # (b, hl, 1, hd)
    k = k.transpose(0, 2, 1, 3)                      # (b, kvl, 1, hd)
    v = v.transpose(0, 2, 1, 3)
    page_ids = jnp.take_along_axis(page_table, (lengths // ps)[:, None],
                                   axis=1)[:, 0]
    kp = paged_update_cache(pool_kv["k"], k, page_ids, lengths % ps)
    vp = paged_update_cache(pool_kv["v"], v, page_ids, lengths % ps)
    if backend == "paged" and paged_kernel.use_kernel():
        attn = paged_kernel.paged_attention(q, kp, vp, page_table,
                                            lengths + 1)
    else:
        attn = decode_attention(ctx, q, paged_gather(kp, page_table),
                                paged_gather(vp, page_table), lengths + 1)
    b, hl = q.shape[:2]
    attn = attn.transpose(0, 2, 1, 3).reshape(b, 1, hl * cfg.hd)
    out = attn @ gather_fsdp(ctx, p["wo"], 1)
    return sp_out(ctx, out), {"k": kp, "v": vp}


# ========================= MLA (deepseek-v3) ==========================

def mla_attention(ctx: ShardCtx, cfg: ModelConfig, p, x, pos,
                  cache=None, cache_pos=None):
    """Multi-head Latent Attention. Train path materializes per-head K/V
    from the compressed kv; decode path uses the absorbed formulation over
    the compressed cache (head-shared, optionally int8-quantized)."""
    hd, rd, kvr = cfg.hd, cfg.qk_rope_dim, cfg.kv_lora_rank
    h = sp_gather(ctx, rmsnorm(x, p["norm"]))
    b, t, d = h.shape
    hl = p["wq_b"].shape[-1] // (hd + rd)
    # --- queries ---
    cq = rmsnorm(h @ gather_fsdp(ctx, p["wq_a"], 0), p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, t, hl, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)
    # --- compressed kv ---
    ckv_full = h @ gather_fsdp(ctx, p["wkv_a"], 0)     # (b, t, kvr + rd)
    ckv = rmsnorm(ckv_full[..., :kvr], p["kv_norm"])
    k_rope = rope(ckv_full[..., None, kvr:], pos, cfg.rope_theta)  # (b,t,1,rd)

    if cache is None:
        kv = (ckv @ p["wkv_b"]).reshape(b, t, hl, 2 * hd)
        k_nope, v = kv[..., :hd], kv[..., hd:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, t, hl, rd))],
                            axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        attn = blocked_attention(qf.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), causal=True)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, t, hl * hd)
        out = sp_out(ctx, attn @ gather_fsdp(ctx, p["wo"], 1))
        # quantized compressed cache, collected by prefill (DCE'd in train)
        sc = jnp.max(jnp.abs(ckv), axis=-1, keepdims=True) / 127.0 + 1e-8
        new_cache = {"ckv": jnp.round(ckv / sc).astype(jnp.int8),
                     "scale": sc.astype(jnp.float32),
                     "krope": k_rope[:, :, 0]}
        return out, new_cache

    # ---- absorbed decode over the compressed cache ----
    wkv_b = p["wkv_b"].reshape(kvr, hl, 2 * hd)
    wk, wv = wkv_b[..., :hd], wkv_b[..., hd:]
    # absorb K up-projection into the query
    q_c = jnp.einsum("bthd,rhd->bthr", q_nope, wk)     # (b, t, hl, kvr)
    # quantized cache update (int8 + per-token scale)
    ckv_t = ckv[:, 0]                                   # (b, kvr) t == 1
    scale = jnp.max(jnp.abs(ckv_t), axis=-1, keepdims=True) / 127.0 + 1e-8
    ckv_q = jnp.round(ckv_t / scale).astype(jnp.int8)
    c_cache = lax.dynamic_update_slice(
        cache["ckv"], ckv_q[:, None], (0, cache_pos, 0))
    s_cache = lax.dynamic_update_slice(
        cache["scale"], scale.astype(jnp.float32)[:, None], (0, cache_pos, 0))
    r_cache = lax.dynamic_update_slice(
        cache["krope"], k_rope[:, :, 0].astype(cache["krope"].dtype),
        (0, cache_pos, 0))
    new_cache = {"ckv": c_cache, "scale": s_cache, "krope": r_cache}
    cdeq = c_cache.astype(jnp.float32) * s_cache       # (b, S, kvr)
    s_nope = jnp.einsum("bthr,bsr->bths", q_c.astype(jnp.float32), cdeq)
    s_rope = jnp.einsum("bthd,bsd->bths", q_rope.astype(jnp.float32),
                        r_cache.astype(jnp.float32))
    s = (s_nope + s_rope) * ((hd + rd) ** -0.5)
    valid = jnp.arange(c_cache.shape[1]) <= cache_pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bths,bsr->bthr", w, cdeq)        # compressed-space out
    attn = jnp.einsum("bthr,rhd->bthd", o_c, wv.astype(jnp.float32))
    attn = attn.astype(x.dtype).reshape(b, t, hl * hd)
    out = attn @ gather_fsdp(ctx, p["wo"], 1)
    return lax.psum(out, ctx.model_axis), new_cache


# ================================ MoE =================================

def moe_block(ctx: ShardCtx, cfg: ModelConfig, p, x):
    """Top-k routed experts, expert-parallel over the 'model' axis with
    expert-side top-C token selection (capacity-bounded, no all_to_all:
    activations are TP-replicated so each shard runs its local experts).
    p: router (d, E_local), w_gate/w_up (El, d, ffe), w_down (El, ffe, d),
    optional shared expert (d, ff_sh_local)."""
    h = sp_gather(ctx, rmsnorm(x, p["norm"]))
    b, t, d = h.shape
    xt = h.reshape(b * t, d)
    n_tok = b * t
    logits_l = (xt @ p["router"]).astype(jnp.float32)        # (T, El)
    logits = lax.all_gather(logits_l, ctx.model_axis, axis=1, tiled=True)
    gates = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    top_g, top_e = lax.top_k(gates, cfg.top_k)
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)
    full = jnp.zeros_like(gates).at[jnp.arange(n_tok)[:, None], top_e].set(top_g)
    el = p["router"].shape[-1]
    e_lo = lax.axis_index(ctx.model_axis) * el
    local_gates = lax.dynamic_slice(full, (0, e_lo), (n_tok, el))  # (T, El)
    cap = int(n_tok * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    cap = min(cap, n_tok)
    # expert-side top-C token selection
    g_sel, idx = lax.top_k(local_gates.T, cap)                # (El, C)
    xe = jnp.take(xt, idx.reshape(-1), axis=0).reshape(el, cap, d)
    wg = gather_fsdp(ctx, p["w_gate"], 1)
    wu = gather_fsdp(ctx, p["w_up"], 1)
    wd = gather_fsdp(ctx, p["w_down"], 2)
    gh = jnp.einsum("ecd,edf->ecf", xe, wg)
    uh = jnp.einsum("ecd,edf->ecf", xe, wu)
    hh = jax.nn.silu(gh.astype(jnp.float32)).astype(x.dtype) * uh
    ye = jnp.einsum("ecf,efd->ecd", hh, wd)
    ye = ye * g_sel[..., None].astype(ye.dtype)
    out = jnp.zeros((n_tok, d), ye.dtype).at[idx.reshape(-1)].add(
        ye.reshape(-1, d))
    if "sh_gate" in p:  # shared experts (deepseek): ordinary TP mlp, no norm
        g = xt @ gather_fsdp(ctx, p["sh_gate"], 0)
        u = xt @ gather_fsdp(ctx, p["sh_up"], 0)
        out = out + (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
                     ) @ gather_fsdp(ctx, p["sh_down"], 1)
    out = sp_out(ctx, out.reshape(b, t, d))
    # auxiliary load-balance loss (switch-style)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(full > 0, axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return out, aux


# =============================== Mamba2 ===============================

def _ssd_chunk_scan(xh, dt, a_log, bmat, cmat, chunk: int):
    """SSD chunked scan (Mamba-2). xh: (b, t, nh, hp); dt: (b, t, nh)
    (post-softplus); a_log: (nh,) (negative); bmat/cmat: (b, t, N).
    Returns y: (b, t, nh, hp) and final state (b, nh, hp, N)."""
    b, t, nh, hp = xh.shape
    n = bmat.shape[-1]
    pad = (-t) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    tc = xh.shape[1]
    nc = tc // chunk
    xc = xh.reshape(b, nc, chunk, nh, hp)
    dtc = dt.reshape(b, nc, chunk, nh)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)
    da = dtc * a_log[None, None, None, :]               # (b, nc, Q, nh) <= 0
    cum = jnp.cumsum(da, axis=2)

    def chunk_body(state, ins):
        xq, dq, bq, cq, daq, cumq = ins                 # leading axis = chunks
        # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
        rel = cumq[:, :, None, :] - cumq[:, None, :, :]  # (b, Q, Q, nh)
        iq = jnp.arange(chunk)
        maskq = iq[:, None] >= iq[None, :]
        dec = jnp.where(maskq[None, :, :, None], jnp.exp(rel), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cq, bq)          # (b, Q, Q)
        w = cb[..., None] * dec * dq[:, None, :, :]      # (b, Q, Q, nh)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # inter-chunk: y[i] += (C_i . S_prev) * exp(cum_i)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, state, jnp.exp(cumq))
        # state update: S = S*exp(cum_last) + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
        last = cumq[:, -1:, :]                            # (b, 1, nh)
        wj = jnp.exp(last - cumq) * dq                    # (b, Q, nh)
        decay_last = jnp.exp(cumq[:, -1, :])              # (b, nh)
        s_chunk = jnp.einsum("bjh,bjn,bjhp->bhpn", wj, bq, xq)
        state = state * decay_last[:, :, None, None] + s_chunk
        return state, y_intra + y_inter

    state0 = jnp.zeros((b, nh, hp, n), jnp.float32)
    ins = tuple(z.transpose(1, 0, *range(2, z.ndim))
                for z in (xc, dtc, bc, cc, da, cum))
    state, yc = lax.scan(chunk_body, state0, ins)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, tc, nh, hp)[:, :t]
    return y, state


def mamba2_block(ctx: ShardCtx, cfg: ModelConfig, p, x, state=None,
                 chunk: int = 128):
    """Mamba-2 (SSD) block, heads sharded over 'model'. Depthwise causal
    conv (k=4) on x/B/C paths. state: (b, nh_local, hp, N) for decode."""
    h = sp_gather(ctx, rmsnorm(x, p["norm"]))
    b, t, d = h.shape
    n = cfg.ssm_state
    di_l = p["w_x"].shape[-1]
    nh_l = p["a_log"].shape[0]
    hp = di_l // nh_l
    xs = h @ gather_fsdp(ctx, p["w_x"], 0)              # (b, t, di_l)
    z = h @ gather_fsdp(ctx, p["w_z"], 0)
    bc = h @ gather_fsdp(ctx, p["w_bc"], 0)              # (b, t, 2N)
    dt_raw = h @ p["w_dt"]   # (b, t, nh_l); w_dt is not FSDP-sharded

    def dconv(sig, w, prev=None):
        # causal depthwise conv, kernel k. sig: (b, t, c), w: (k, c)
        k = w.shape[0]
        if prev is None:
            padded = jnp.pad(sig, ((0, 0), (k - 1, 0), (0, 0)))
        else:
            padded = jnp.concatenate([prev, sig], axis=1)
        out = sum(padded[:, i:i + sig.shape[1]] * w[i] for i in range(k))
        return out, padded[:, -(k - 1):]

    if state is not None:
        xs, cs_x = dconv(xs, p["conv_x"], state["conv_x"])
        bc, cs_bc = dconv(bc, p["conv_bc"], state["conv_bc"])
    else:
        xs, cs_x = dconv(xs, p["conv_x"])
        bc, cs_bc = dconv(bc, p["conv_bc"])
    conv_state = {"conv_x": cs_x.astype(jnp.float32),
                  "conv_bc": cs_bc.astype(jnp.float32)}
    xs = jax.nn.silu(xs.astype(jnp.float32))
    bc = jax.nn.silu(bc.astype(jnp.float32))
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(b, t, nh_l, hp)
    a_log = -jnp.exp(p["a_log"].astype(jnp.float32))

    if state is None:
        y, new_s = _ssd_chunk_scan(xh, dt, a_log, bmat, cmat, chunk)
        new_state = {"ssm": new_s, **conv_state}  # prefill final state
    else:
        # single-step recurrence
        s_prev = state["ssm"]
        da = jnp.exp(dt[:, 0] * a_log[None, :])          # (b, nh)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], bmat[:, 0], xh[:, 0])
        s_new = s_prev * da[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], s_new)[:, None]
        new_state = {"ssm": s_new, **conv_state}
        y = y.reshape(b, 1, nh_l, hp)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = (y.reshape(b, t, di_l) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ gather_fsdp(ctx, p["w_out"], 1)
    return sp_out(ctx, out), new_state


# =============================== xLSTM ================================

def mlstm_block(ctx: ShardCtx, cfg: ModelConfig, p, x, state=None,
                chunk: int = 128):
    """mLSTM (matrix memory) block, chunkwise-parallel, heads sharded.

    Linear-attention-like with exponential input gate and sigmoid forget
    gate accumulated in log space (float32, clipped)."""
    h = sp_gather(ctx, rmsnorm(x, p["norm"]))
    b, t, d = h.shape
    di_l = p["w_q"].shape[-1]
    nh_l = p["w_if"].shape[-1] // 2
    hp = di_l // nh_l
    q = (h @ gather_fsdp(ctx, p["w_q"], 0)).reshape(b, t, nh_l, hp)
    k = (h @ gather_fsdp(ctx, p["w_k"], 0)).reshape(b, t, nh_l, hp)
    v = (h @ gather_fsdp(ctx, p["w_v"], 0)).reshape(b, t, nh_l, hp)
    z = h @ gather_fsdp(ctx, p["w_z"], 0)
    gif = h @ gather_fsdp(ctx, p["w_if"], 0)             # (b, t, 2*nh_l)
    i_raw = gif[..., :nh_l].astype(jnp.float32)
    f_raw = gif[..., nh_l:].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_raw)                     # <= 0
    qf = q.astype(jnp.float32) * hp ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if state is None:
        # chunkwise: identical skeleton to SSD with per-head scalar decay
        pad = (-t) % chunk
        if pad:
            qf, kf, vf = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                          for a in (qf, kf, vf))
            log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
            i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=-30.)
        tc = qf.shape[1]
        nc = tc // chunk
        shp = (b, nc, chunk, nh_l)
        qc = qf.reshape(b, nc, chunk, nh_l, hp)
        kc = kf.reshape(b, nc, chunk, nh_l, hp)
        vc = vf.reshape(b, nc, chunk, nh_l, hp)
        fc = jnp.clip(log_f.reshape(shp), -30.0, 0.0)
        ic = jnp.exp(jnp.clip(i_raw.reshape(shp), -30.0, 10.0))
        cum = jnp.cumsum(fc, axis=2)

        def body(carry, ins):
            c_state, n_state = carry                     # (b,nh,hp,hp),(b,nh,hp)
            qq, kk, vv, cumq, ii = ins
            rel = cumq[:, :, None, :] - cumq[:, None, :, :]
            iq = jnp.arange(chunk)
            maskq = iq[:, None] >= iq[None, :]
            dec = jnp.where(maskq[None, :, :, None], jnp.exp(rel), 0.0)
            w = jnp.einsum("bihp,bjhp->bijh", qq, kk) * dec * ii[:, None]
            y_intra = jnp.einsum("bijh,bjhp->bihp", w, vv)
            n_intra = jnp.einsum("bijh,bjhp->bihp", w, jnp.ones_like(vv[..., :1]))
            ed = jnp.exp(cumq)                           # (b, Q, nh)
            y_inter = jnp.einsum("bihp,bhpv,bih->bihv", qq, c_state, ed)
            n_inter = jnp.einsum("bihp,bhp,bih->bih", qq, n_state, ed)[..., None]
            last = jnp.exp(cumq[:, -1, :])               # (b, nh)
            wj = jnp.exp(cumq[:, -1:, :] - cumq) * ii    # (b, Q, nh)
            c_state = (c_state * last[:, :, None, None]
                       + jnp.einsum("bjh,bjhp,bjhv->bhpv", wj, kk, vv))
            n_state = (n_state * last[:, :, None]
                       + jnp.einsum("bjh,bjhp->bhp", wj, kk))
            denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)
            return (c_state, n_state), (y_intra + y_inter) / denom

        c0 = jnp.zeros((b, nh_l, hp, hp), jnp.float32)
        n0 = jnp.zeros((b, nh_l, hp), jnp.float32)
        ins = tuple(a.transpose(1, 0, *range(2, a.ndim))
                    for a in (qc, kc, vc, cum, ic))
        (cS, nS), yc = lax.scan(body, (c0, n0), ins)
        y = yc.transpose(1, 0, 2, 3, 4).reshape(b, tc, nh_l, hp)[:, :t]
        new_state = {"c": cS, "n": nS}  # prefill final state
    else:
        cS, nS = state["c"], state["n"]
        f1 = jnp.exp(jnp.clip(log_f[:, 0], -30.0, 0.0))
        i1 = jnp.exp(jnp.clip(i_raw[:, 0], -30.0, 10.0))
        cS = cS * f1[..., None, None] + i1[..., None, None] * jnp.einsum(
            "bhp,bhv->bhpv", kf[:, 0], vf[:, 0])
        nS = nS * f1[..., None] + i1[..., None] * kf[:, 0]
        num = jnp.einsum("bhp,bhpv->bhv", qf[:, 0], cS)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf[:, 0], nS)), 1.0)
        y = (num / den[..., None])[:, None]
        new_state = {"c": cS, "n": nS}
    y = (y.reshape(b, t, di_l) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ gather_fsdp(ctx, p["w_out"], 1)
    return sp_out(ctx, out), new_state


def slstm_block(ctx: ShardCtx, cfg: ModelConfig, p, x, state=None):
    """sLSTM (scalar memory, exponential gating with stabilizer), heads
    sharded over 'model'; sequential lax.scan over time."""
    hn = sp_gather(ctx, rmsnorm(x, p["norm"]))
    b, t, d = hn.shape
    di_l = p["w_in"].shape[-1] // 4
    nh_l = p["r"].shape[0]
    hp = di_l // nh_l
    gates_x = (hn @ gather_fsdp(ctx, p["w_in"], 0)).astype(jnp.float32)

    def step(carry, gx):
        hprev, c, nrm, m = carry                          # (b, nh, hp) each, m (b, nh,hp)
        rec = jnp.einsum("bhp,hpq->bhq", hprev, p["r"].astype(jnp.float32))
        g = gx.reshape(b, nh_l, 4 * hp) + jnp.concatenate([rec] * 4, axis=-1)
        zi, ii, ff, oo = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zi)
        log_f = jax.nn.log_sigmoid(ff)
        m_new = jnp.maximum(log_f + m, ii)
        i_p = jnp.exp(ii - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c = f_p * c + i_p * zt
        nrm = f_p * nrm + i_p
        hcur = jax.nn.sigmoid(oo) * c / jnp.maximum(nrm, 1.0)
        return (hcur, c, nrm, m_new), hcur

    zeros = jnp.zeros((b, nh_l, hp), jnp.float32)
    if state is not None:
        carry0 = (state["h"], state["c"], state["n"], state["m"])
    else:
        carry0 = (zeros, zeros, zeros, zeros - 30.0)
    carry, ys = lax.scan(step, carry0, gates_x.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2, 3).reshape(b, t, di_l).astype(x.dtype)
    out = y @ gather_fsdp(ctx, p["w_out"], 1)
    new_state = None
    if state is not None:
        new_state = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    return sp_out(ctx, out), new_state
