"""ResNet-50 in pure JAX (paper IV: trained on CIFAR-100 with OptINC).

NHWC, GroupNorm instead of BatchNorm (no cross-device batch stats ⇒ the
gradient sync is the ONLY cross-device communication, exactly the quantity
OptINC replaces). CIFAR variant: 3x3 stem, no max-pool.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

BLOCKS = (3, 4, 6, 3)
WIDTHS = (64, 128, 256, 512)


def conv(x, w, stride=1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def groupnorm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c).astype(x.dtype) * scale + bias


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan)


def init_params(key, classes: int = 100):
    keys = iter(jax.random.split(key, 256))
    p = {"stem": _conv_init(next(keys), 3, 3, 3, 64),
         "stem_s": jnp.ones((64,)), "stem_b": jnp.zeros((64,))}
    cin = 64
    for si, (nb, w) in enumerate(zip(BLOCKS, WIDTHS)):
        for bi in range(nb):
            stride = 2 if (bi == 0 and si > 0) else 1
            blk = {
                "c1": _conv_init(next(keys), 1, 1, cin, w),
                "c2": _conv_init(next(keys), 3, 3, w, w),
                "c3": _conv_init(next(keys), 1, 1, w, 4 * w),
            }
            for j in (1, 2, 3):
                cw = w if j < 3 else 4 * w
                blk[f"s{j}"] = jnp.ones((cw,))
                blk[f"b{j}"] = jnp.zeros((cw,))
            if cin != 4 * w or stride != 1:
                blk["proj"] = _conv_init(next(keys), 1, 1, cin, 4 * w)
                blk["proj_s"] = jnp.ones((4 * w,))
                blk["proj_b"] = jnp.zeros((4 * w,))
            p[f"block{si}_{bi}"] = blk
            cin = 4 * w
    p["head_w"] = jax.random.normal(next(keys), (cin, classes)) * 0.01
    p["head_b"] = jnp.zeros((classes,))
    return p


def forward(p, x):
    x = groupnorm(conv(x, p["stem"]), p["stem_s"], p["stem_b"])
    x = jax.nn.relu(x)
    cin = 64
    for si, (nb, w) in enumerate(zip(BLOCKS, WIDTHS)):
        for bi in range(nb):
            stride = 2 if (bi == 0 and si > 0) else 1
            blk = p[f"block{si}_{bi}"]
            h = jax.nn.relu(groupnorm(conv(x, blk["c1"]), blk["s1"], blk["b1"]))
            h = jax.nn.relu(groupnorm(conv(h, blk["c2"], stride), blk["s2"],
                                      blk["b2"]))
            h = groupnorm(conv(h, blk["c3"]), blk["s3"], blk["b3"])
            if "proj" in blk:
                x = groupnorm(conv(x, blk["proj"], stride), blk["proj_s"],
                              blk["proj_b"])
            x = jax.nn.relu(x + h)
            cin = 4 * w
    x = x.mean(axis=(1, 2))
    return x @ p["head_w"] + p["head_b"]


def loss_fn(p, images, labels):
    logits = forward(p, images)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, acc
