"""Model configuration dataclass shared by the whole zoo."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qk_norm: bool = False        # qwen3 / chameleon
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden (d_ff is the dense-layer hidden)
    first_dense_layers: int = 0  # deepseek-v3 keeps first layers dense
    capacity_factor: float = 1.25
    # --- MLA (deepseek-v3) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    mtp: bool = False            # multi-token-prediction auxiliary head
    # --- SSM / hybrid ---
    ssm: str = ""                # "" | "mamba2" | "xlstm"
    ssm_state: int = 0
    attn_every: int = 0          # hybrid: one (shared) attention block every k layers
    slstm_every: int = 0         # xlstm: sLSTM block every k layers (rest mLSTM)
    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500       # stub frontend sequence length
    # --- misc ---
    rope_theta: float = 500000.0
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate total parameters (for roofline MODEL_FLOPS)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.ssm == "xlstm":
            per = 8 * d * d  # qkv+gates+out and up/down projections
            return emb + L * per
        attn = d * (self.n_heads * self.hd) * 2 + d * (self.n_kv_heads * self.hd) * 2
        if self.mla:
            attn = (d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (self.hd + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * self.hd * 2
                    + self.n_heads * self.hd * d)
        dense_ff = 3 * d * self.d_ff
        if self.moe:
            moe_ff = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            n_moe = L - self.first_dense_layers
            ff_total = self.first_dense_layers * dense_ff + n_moe * moe_ff
        else:
            ff_total = L * dense_ff
        if self.ssm == "mamba2":
            n_attn = L // self.attn_every if self.attn_every else 0
            n_ssm = L - n_attn
            per_ssm = 2 * d * 2 * d + 2 * d * d  # in-proj (x,z) + out-proj, ~Mamba2
            return emb + n_ssm * per_ssm + n_attn * (attn + dense_ff) + ff_total * 0
        return emb + L * attn + ff_total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        dense_like = self.param_count()
        moe_all = 3 * d * self.moe_d_ff * self.n_experts
        moe_act = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        n_moe = L - self.first_dense_layers
        return dense_like - n_moe * (moe_all - moe_act) + 0
