"""Live-membership -> mesh-topology derivation.

The cascade's two-level split is (pods, dp): member ``i`` of the sorted
live enumeration sits in pod ``i // dp``, and a pod is usable only when
ALL of its ``dp`` workers are live — one dead worker drains the whole
pod (its level-1 OptINC group cannot form).  ``derive_topology`` is
therefore a floor-division: the survivors re-form
``min(base.pods, n_live // base.dp)`` full pods, capped at the
configured base (joins beyond the base world are spares, not growth
past the provisioned fabric).

Duck-typed over any dataclass with ``pods``/``dp`` fields (MeshSpec) so
this module needs no repro.api import.
"""
from __future__ import annotations

import dataclasses


class ElasticError(RuntimeError):
    """The live membership cannot form any valid topology."""


def derive_topology(n_live: int, base_mesh):
    """The mesh the ``n_live`` survivors re-form, given the run's base
    (maximum) topology.  Returns ``base_mesh`` itself when nothing
    changes; raises ElasticError below one full pod."""
    pods = min(base_mesh.pods, n_live // base_mesh.dp)
    if pods < 1:
        raise ElasticError(
            f"{n_live} live member(s) cannot form one full pod of "
            f"dp={base_mesh.dp} (base topology ({base_mesh.pods}, "
            f"{base_mesh.dp}))")
    if pods == base_mesh.pods:
        return base_mesh
    return dataclasses.replace(base_mesh, pods=pods)


def member_pod(rank: int, base_mesh) -> int:
    """Which pod the member at sorted-live index ``rank`` belongs to."""
    return rank // base_mesh.dp
