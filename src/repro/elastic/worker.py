"""Per-process elastic pod agent.

  PYTHONPATH=src python -m repro.elastic.worker --member w0 \
      --workdir results/elastic_run --world 4 \
      --arch minitron_4b --smoke-config --sync cascade --mesh 2x1 \
      --elastic --allow-reshard --ckpt-dir results/elastic_run/ckpt ...

Every process joins the file/heartbeat registry under ``--members-dir``
(default ``<workdir>/members``) and beats from a daemon thread.  The
LOWEST live member id is the leader: it runs the ElasticTrainSession
(training the whole emulated device mesh in-process — the repo's
emulation model keeps all "N devices" in one process, so followers are
membership participants, not compute shards).  Followers idle-beat and
watch for the DONE marker; if the leader dies, the next-lowest live
member takes over and resumes from the shared checkpoint directory —
leader failover IS a reshard-resume.

On completion the leader writes ``<workdir>/result.json`` (history,
membership events, state fingerprint) and the ``DONE`` marker that
releases the followers.  ``chaos.run_chaos`` SIGKILLs one of these
processes mid-run and asserts the survivors recover.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


def build_spec(ns: dict, workdir: pathlib.Path):
    from ..api.spec import RunSpec
    import dataclasses
    base = (RunSpec.load(ns.pop("spec")) if "spec" in ns else RunSpec())
    spec = base.apply_cli(ns)
    if not spec.ckpt.dir:
        spec = dataclasses.replace(
            spec, ckpt=dataclasses.replace(
                spec.ckpt, dir=str(workdir / "ckpt")))
    if not spec.elastic.dir:
        spec = dataclasses.replace(
            spec, elastic=dataclasses.replace(
                spec.elastic, dir=str(workdir / "members")))
    return spec.validate()


def main(argv=None) -> int:
    from ..api.spec import RunSpec, SpecError
    from .membership import Membership

    ap = argparse.ArgumentParser(
        description=__doc__, argument_default=argparse.SUPPRESS,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--member", required=True,
                    help="this process's registry identity (e.g. w0)")
    ap.add_argument("--workdir", required=True,
                    help="shared run directory (registry, checkpoints, "
                         "result.json, DONE marker)")
    ap.add_argument("--world", type=int, default=0,
                    help="expected initial member count (wait for all of "
                         "them before electing a leader; 0 = don't wait)")
    RunSpec.add_args(ap)
    ns = vars(ap.parse_args(argv))
    member = ns.pop("member")
    workdir = pathlib.Path(ns.pop("workdir"))
    world = ns.pop("world", 0)
    workdir.mkdir(parents=True, exist_ok=True)

    try:
        spec = build_spec(ns, workdir)
    except SpecError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    e = spec.elastic
    mem = Membership(e.members_dir(spec.ckpt.dir), member=member,
                     heartbeat_s=e.heartbeat_s, timeout_s=e.timeout_s)
    mem.join()
    mem.start_heartbeat()
    done_marker = workdir / "DONE"
    try:
        # hold leadership checks until the expected world assembles (or a
        # grace period passes) so a fast-starting high-id member does not
        # crown itself before w0 arrives
        deadline = time.time() + max(10.0 * e.heartbeat_s, 5.0)
        while world and len(mem.live()) < world and time.time() < deadline:
            time.sleep(min(e.heartbeat_s, 0.2))
        while not done_marker.exists():
            live = mem.live()
            if live and live[0] == member:
                return _lead(spec, mem, workdir, done_marker)
            time.sleep(min(e.heartbeat_s, 0.5))
        return 0
    finally:
        mem.leave()


def _lead(spec, mem, workdir: pathlib.Path, done_marker: pathlib.Path) -> int:
    from .session import ElasticTrainSession
    from .topology import ElasticError

    print(f"{mem.member}: leading (live={mem.live()!r})", flush=True)
    session = ElasticTrainSession(spec, membership=mem)
    code = 0
    try:
        history = session.run()
        result = {
            "leader": mem.member,
            "final_step": session.session.step if session.session else 0,
            "history": history,
            "events": session.events,
            "state_fingerprint": spec.state_fingerprint(),
        }
    except ElasticError as err:
        print(f"unrecoverable membership loss: {err}", file=sys.stderr)
        result = {"leader": mem.member, "error": str(err),
                  "history": [], "events": session.events,
                  "state_fingerprint": spec.state_fingerprint()}
        code = 3
    tmp = workdir / "result.json.tmp"
    tmp.write_text(json.dumps(result, indent=1))
    tmp.replace(workdir / "result.json")
    done_marker.write_text(mem.member)
    return code


if __name__ == "__main__":
    sys.exit(main())
