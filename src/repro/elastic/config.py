"""ElasticConfig: the frozen elastic-membership half of a RunSpec.

Lives in its own module (no repro.api imports) so ``api.spec`` can embed
it in RunSpec without a cycle, exactly like ``serving.config``: spec ->
elastic.config only.  Field checks raise ValueError from
``__post_init__`` — ``_from_dict`` wraps those in SpecError on the JSON
path, and RunSpec.validate() adds the cross-field rules (``--elastic``
needs a checkpoint dir and a sync backend with topology to re-derive).
"""
from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Elastic-membership runtime knobs.

    ``enabled`` makes world size a runtime property: the session watches a
    file/heartbeat membership registry at step boundaries and, when a pod
    drops or joins, re-derives the collective topology and resumes from
    the latest checkpoint on the new mesh shape.  ``dir`` is the registry
    directory ("" = ``<ckpt.dir>/members``).  A member is considered dead
    after ``timeout_s`` without a heartbeat (0 = 3 x ``heartbeat_s``).
    ``allow_reshard`` permits ``--resume`` onto a different mesh shape
    even with the elastic loop off (gate for the compatible-reshard
    checkpoint path).  ``evict_after`` arms the StragglerWatchdog's
    escalation: that many CONSECUTIVE straggler flags on the same rank
    reports the member to the registry as suspect (0 = observe only).
    """
    enabled: bool = False
    dir: str = ""             # membership registry ("" = <ckpt.dir>/members)
    heartbeat_s: float = 1.0  # beat period; liveness poll granularity
    timeout_s: float = 0.0    # declare-dead threshold (0 = 3 x heartbeat_s)
    allow_reshard: bool = False
    evict_after: int = 0      # watchdog flags before suspect-report (0 = off)

    def __post_init__(self):
        if self.heartbeat_s <= 0:
            raise ValueError(f"elastic.heartbeat_s must be > 0, "
                             f"got {self.heartbeat_s}")
        if self.timeout_s < 0:
            raise ValueError(f"elastic.timeout_s must be >= 0, "
                             f"got {self.timeout_s}")
        if self.evict_after < 0:
            raise ValueError(f"elastic.evict_after must be >= 0, "
                             f"got {self.evict_after}")

    @property
    def effective_timeout_s(self) -> float:
        return self.timeout_s or 3.0 * self.heartbeat_s

    def members_dir(self, ckpt_dir: str = "") -> str:
        """Where the registry lives for a run checkpointing to
        ``ckpt_dir`` (an explicit ``dir`` always wins)."""
        return self.dir or os.path.join(ckpt_dir, "members")
