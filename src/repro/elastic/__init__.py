"""repro.elastic: elastic membership runtime — world size as a runtime
property of a training run.

- ``config``     — frozen ElasticConfig (embedded in RunSpec as ``elastic``)
- ``membership`` — file/heartbeat member registry (multi-process safe)
- ``topology``   — live-count -> cascade (pods, dp) mesh derivation
- ``session``    — ElasticTrainSession: detect / re-derive / reshard-resume
                   loop around TrainSession (lazy: it imports repro.api)
- ``worker``     — per-process pod agent + leader election (lazy, same)
- ``chaos``      — multi-process chaos driver: spawn N workers, SIGKILL
                   one, assert recovery (lazy, same)
"""
from .config import ElasticConfig
from .membership import Membership
from .topology import ElasticError, derive_topology, member_pod

__all__ = [
    "ElasticConfig", "Membership", "ElasticError", "derive_topology",
    "member_pod", "ElasticTrainSession", "MembershipMonitor", "run_chaos",
]

_LAZY = {"ElasticTrainSession": "session",
         "MembershipMonitor": "session",
         "run_chaos": "chaos"}


def __getattr__(name):
    # session/worker/chaos import repro.api (which imports elastic.config);
    # loading them lazily keeps `import repro.elastic` cycle-free
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
