"""Multi-process chaos driver: spawn workers, SIGKILL one, assert recovery.

The headline elastic proof (EXPERIMENTS.md §Elastic training): four
worker processes join the registry over a ``(pods=2, dp=2)`` cascade
base topology; once the run has checkpointed past ``kill_after_step``,
one worker is SIGKILLed (no SIGTERM grace, no atexit — its member file
simply goes stale).  Member ``i`` of the sorted enumeration sits in pod
``i // dp``, and a pod needs ALL its dp members, so the loss of one
worker drains a whole pod: the survivors re-derive the ``(1, 2)``
topology and the leader reshard-resumes from the last checkpoint.

``run_chaos`` returns the leader's result.json augmented with driver
observations (kill time, detection latency, worker exit codes).  Used by
``tests/test_elastic_chaos.py`` and ``benchmarks/elastic.py`` (the CI
chaos smoke).
"""
from __future__ import annotations

import json
import os
import pathlib
import signal
import subprocess
import sys
import time


def _worker_env(devices: int, repo_root: pathlib.Path) -> dict:
    return {"PYTHONPATH": str(repo_root / "src"),
            "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
            "HOME": os.environ.get("HOME", "/root"),
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}


def default_train_args(workdir: pathlib.Path, steps: int = 8) -> list:
    """The chaos scenario: smallest smoke arch, 2-pod cascade, a
    checkpoint every step (the recovery point is always fresh), fast
    heartbeats so detection fits a test budget."""
    return ["--arch", "minitron_4b", "--smoke-config",
            "--sync", "cascade", "--mesh", "2x1", "--pods", "2",
            "--steps", str(steps), "--global-batch", "4",
            "--seq-len", "32", "--bucket-mb", "1",
            "--ckpt-dir", str(workdir / "ckpt"), "--ckpt-every", "1",
            "--elastic", "--allow-reshard", "--heartbeat-s", "0.15",
            "--watchdog", "0"]


def run_chaos(workdir, n_workers: int = 4, kill_index: int = 3,
              kill_after_step: int = 0, steps: int = 12,
              timeout_s: float = 900.0, train_args: list | None = None,
              log=print) -> dict:
    """Run the kill-one-worker scenario; returns the recovery report.

    Raises RuntimeError when the run does not complete (leader died, no
    checkpoint appeared, or the deadline passed).
    """
    from ..checkpoint.ckpt import latest_step

    workdir = pathlib.Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    repo_root = pathlib.Path(__file__).resolve().parents[3]
    args = (default_train_args(workdir, steps=steps)
            if train_args is None else list(train_args))
    env = _worker_env(devices=n_workers, repo_root=repo_root)
    logf = open(workdir / "workers.log", "w")
    procs = []
    try:
        for i in range(n_workers):
            cmd = [sys.executable, "-m", "repro.elastic.worker",
                   "--member", f"w{i}", "--workdir", str(workdir),
                   "--world", str(n_workers)] + args
            procs.append(subprocess.Popen(
                cmd, env=env, cwd=str(workdir), stdout=logf,
                stderr=subprocess.STDOUT))
        deadline = time.time() + timeout_s
        ckpt_dir = workdir / "ckpt"

        def leader_alive():
            return any(p.poll() is None for j, p in enumerate(procs)
                       if j != kill_index)

        # phase 1: wait for training to checkpoint past the kill point.
        # The default kill point is the step-0 checkpoint: step 1 is the
        # slow donation-re-layout execution (seconds), so the victim's
        # heartbeat goes stale and the monitor's step-boundary poll fires
        # before the remaining (sub-100ms) steps can race past it.
        while True:
            s = latest_step(ckpt_dir)
            if s is not None and s >= kill_after_step:
                break
            if not leader_alive():
                raise RuntimeError(
                    f"all candidate leaders exited before step "
                    f"{kill_after_step} (see {workdir / 'workers.log'})")
            if time.time() > deadline:
                raise RuntimeError(
                    f"no checkpoint at step >= {kill_after_step} within "
                    f"{timeout_s:.0f}s (latest: {s})")
            time.sleep(0.25)
        victim = procs[kill_index]
        victim.send_signal(signal.SIGKILL)
        victim.wait()
        t_kill = time.time()
        log(f"killed worker w{kill_index} (pid {victim.pid}) after "
            f"checkpoint step {latest_step(ckpt_dir)}")

        # phase 2: wait for the survivors to finish the run
        result_p = workdir / "result.json"
        done_p = workdir / "DONE"
        while not (done_p.exists() and result_p.exists()):
            if not leader_alive() and not done_p.exists():
                raise RuntimeError(
                    f"survivors exited without completing the run (see "
                    f"{workdir / 'workers.log'})")
            if time.time() > deadline:
                raise RuntimeError(
                    f"no recovery within {timeout_s:.0f}s of launch")
            time.sleep(0.25)
        result = json.loads(result_p.read_text())
        result["kill"] = {"member": f"w{kill_index}",
                          "recover_s": round(time.time() - t_kill, 3)}
        for j, p in enumerate(procs):
            if j != kill_index:
                try:
                    p.wait(timeout=60)
                except subprocess.TimeoutExpired:
                    p.kill()
        result["exit_codes"] = [p.poll() for p in procs]
        return result
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        logf.close()
