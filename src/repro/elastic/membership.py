"""File/heartbeat membership registry.

One JSON file per member in a shared directory — the lowest-common-
denominator coordination substrate that works across the processes of a
multi-process test without a rendezvous server (the hivemind-style
monitor pattern: peers announce themselves and are presumed dead when
their heartbeat goes stale).  All writes are atomic (tmp + os.replace),
so a reader never sees a torn record.

Liveness: a member is live iff its last beat is within ``timeout_s`` AND
it has not been marked suspect since that beat.  ``suspect`` is the
escalation hook the StragglerWatchdog uses — a suspect mark is a
tombstone with a timestamp, cleared automatically by any LATER beat from
the accused member (a recovered straggler re-admits itself).
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time


class Membership:
    """Registry handle; optionally bound to one member identity.

    >>> m = Membership("run/members", member="w0", heartbeat_s=0.5)
    >>> m.join(); m.start_heartbeat()
    >>> m.live()                       # ("w0", ...) across processes
    >>> m.stop_heartbeat(); m.leave()

    Observer use (no ``member``) supports ``live*``/``suspect`` only.
    """

    def __init__(self, direc, member: str | None = None,
                 heartbeat_s: float = 1.0, timeout_s: float = 0.0):
        self.dir = pathlib.Path(direc)
        self.member = member
        self.heartbeat_s = heartbeat_s
        self.timeout_s = timeout_s or 3.0 * heartbeat_s
        self._thread = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ writes
    def _write(self, path: pathlib.Path, record: dict):
        self.dir.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(record))
        os.replace(tmp, path)

    def _member_path(self, member: str) -> pathlib.Path:
        return self.dir / f"{member}.json"

    def join(self):
        if self.member is None:
            raise ValueError("observer Membership (member=None) cannot join")
        now = time.time()
        self._write(self._member_path(self.member),
                    {"member": self.member, "pid": os.getpid(),
                     "joined": now, "time": now})

    def beat(self, now: float | None = None):
        if self.member is None:
            raise ValueError("observer Membership (member=None) cannot beat")
        path = self._member_path(self.member)
        try:
            rec = json.loads(path.read_text())
        except (OSError, ValueError):
            rec = {"member": self.member, "pid": os.getpid(),
                   "joined": time.time()}
        rec["time"] = time.time() if now is None else now
        self._write(path, rec)

    def leave(self):
        if self.member is None:
            return
        self.stop_heartbeat()
        for p in (self._member_path(self.member),
                  self.dir / f"{self.member}.suspect"):
            try:
                p.unlink()
            except OSError:
                pass

    def suspect(self, member: str, reason: str = ""):
        """Mark ``member`` suspect (straggler escalation).  Cleared by any
        later beat from the member itself."""
        self._write(self.dir / f"{member}.suspect",
                    {"member": member, "time": time.time(),
                     "reason": reason,
                     "by": self.member or f"pid{os.getpid()}"})

    # ------------------------------------------------------------ reads
    def members(self) -> dict:
        """All registered member records (live or not), by member id."""
        out = {}
        if not self.dir.exists():
            return out
        for p in sorted(self.dir.glob("*.json")):
            try:
                rec = json.loads(p.read_text())
            except (OSError, ValueError):
                continue  # torn/vanished file: skip this poll
            if isinstance(rec, dict) and "member" in rec:
                out[rec["member"]] = rec
        return out

    def _suspect_time(self, member: str) -> float | None:
        p = self.dir / f"{member}.suspect"
        try:
            return float(json.loads(p.read_text())["time"])
        except (OSError, ValueError, KeyError, TypeError):
            return None

    def live_members(self, now: float | None = None) -> dict:
        """Member records whose heartbeat is fresh and not suspect-marked
        since that beat."""
        now = time.time() if now is None else now
        out = {}
        for member, rec in self.members().items():
            beat = float(rec.get("time", 0.0))
            if now - beat > self.timeout_s:
                continue
            sus = self._suspect_time(member)
            if sus is not None and sus >= beat:
                continue
            out[member] = rec
        return out

    def live(self, now: float | None = None) -> tuple:
        """Sorted live member ids — the canonical world enumeration.
        Rank = index into this tuple; the lowest id is the leader."""
        return tuple(sorted(self.live_members(now)))

    # ------------------------------------------------------------ heartbeat
    def start_heartbeat(self):
        """Beat from a daemon thread every ``heartbeat_s`` (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.heartbeat_s):
                try:
                    self.beat()
                except OSError:
                    pass  # registry dir may vanish at teardown

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop_heartbeat(self):
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2 * self.heartbeat_s)
            self._thread = None
