"""ElasticTrainSession: detect -> re-derive -> reshard-resume loop.

World size becomes a runtime property: an inner ``TrainSession`` trains
on the CURRENT topology while a ``MembershipMonitor`` callback polls the
registry at step boundaries.  When the live set changes enough to change
the derived topology, the monitor requests a stop — PeriodicCheckpoint
(which treats a requested stop like a final step) persists the very step
the change was detected on — and the outer loop:

  1. re-derives the cascade ``(pods, dp)`` axes from the live count
     (``topology.derive_topology``; the 1/N carry grid and the
     ``bytes_on_wire``/``time_on_wire`` inputs follow from the new N
     through ``RunSpec.resolved_sync`` and ``api.build``),
  2. re-warms the photonic runtime for the new N1 — the ONN cache keys
     on (PhotonicsConfig, bits, n_servers), so a previously-seen group
     size is a cache HIT, not a rebuild,
  3. rebuilds the inner session with ``ckpt.resume`` — the compatible-
     reshard restore re-places the saved global arrays onto the new
     mesh's NamedShardings, re-zeroes error-feedback residuals whose
     bucketization changed, and the (step-pure) data pipeline continues
     at the right sample offset,

then keeps training until ``spec.steps`` or an unrecoverable membership
loss (fewer survivors than one full pod -> ElasticError).

``session.events`` records one dict per membership epoch transition
(old/new topology, live set, modeled wire bytes/time) — the chaos test
and ``benchmarks/elastic.py`` read it to assert recovery.
"""
from __future__ import annotations

import dataclasses
import time

from .membership import Membership
from .topology import ElasticError, derive_topology


class MembershipMonitor:
    """Callback that turns registry changes into a session stop request.

    Polls at most once per ``heartbeat_s`` (the registry cannot change
    faster than members beat).  A change that does not change the derived
    topology (e.g. a spare joining an already-full world) is recorded but
    does not interrupt training.
    """

    def __init__(self, membership: Membership, base_mesh,
                 heartbeat_s: float = 1.0):
        self.membership = membership
        self.base_mesh = base_mesh
        self.heartbeat_s = heartbeat_s
        self.live = None            # live set at session start (lazy)
        self.new_mesh = None        # derived topology after the change
        self.changed = False
        self.fatal = None           # ElasticError when below one pod
        self._last_poll = 0.0
        self.detected_step = None
        self.detected_at = None

    # Callback protocol (duck-typed: api.callbacks.Callback has the same
    # hook names; no repro.api import needed here)
    def on_train_start(self, session):
        if self.live is None:
            self.live = self.membership.live()

    def on_step(self, session, record):
        now = time.time()
        if now - self._last_poll < self.heartbeat_s:
            return
        self._last_poll = now
        live = self.membership.live()
        if live == self.live:
            return
        self.live = live
        try:
            mesh = derive_topology(len(live), self.base_mesh)
        except ElasticError as e:
            self.fatal = e
            session.request_stop()
            return
        if mesh != session.spec.mesh:
            self.changed = True
            self.new_mesh = mesh
            self.detected_step = record["step"]
            self.detected_at = now
            record["membership_change"] = list(live)
            session.request_stop()

    def on_step_end(self, session, record):
        self.on_step(session, record)

    def on_checkpoint(self, session, step):
        pass

    def on_membership_change(self, old_mesh, new_mesh, step):
        pass

    def on_train_end(self, session):
        pass


class ElasticTrainSession:
    """Train one RunSpec with membership-elastic topology.

    >>> spec = RunSpec(..., elastic=ElasticConfig(enabled=True), ...)
    >>> session = ElasticTrainSession(spec)
    >>> history = session.run()     # spans membership epochs
    >>> session.events              # one dict per topology transition
    """

    def __init__(self, spec, callbacks: list | None = None,
                 membership: Membership | None = None):
        from ..api.spec import SpecError
        spec.validate()
        if not spec.elastic.enabled:
            raise SpecError("ElasticTrainSession needs elastic.enabled "
                            "(--elastic); use TrainSession for static runs")
        self.spec = spec
        self.base_mesh = spec.mesh
        e = spec.elastic
        self.membership = membership if membership is not None else \
            Membership(e.members_dir(spec.ckpt.dir),
                       heartbeat_s=e.heartbeat_s, timeout_s=e.timeout_s)
        self.user_callbacks = list(callbacks) if callbacks else []
        self.events = []
        self.session = None          # current inner TrainSession
        self.history = []

    # ------------------------------------------------------------ quorum
    def wait_for_quorum(self, want: int | None = None,
                        grace_s: float | None = None) -> tuple:
        """Block until the full base world (or ``want`` members) is live,
        or until ``grace_s`` passes with at least one full pod.  Raises
        ElasticError if even one pod never forms."""
        e = self.spec.elastic
        want = (self.base_mesh.pods * self.base_mesh.dp
                if want is None else want)
        grace = (max(10.0 * e.heartbeat_s, 5.0)
                 if grace_s is None else grace_s)
        deadline = time.time() + grace
        while True:
            live = self.membership.live()
            if len(live) >= want:
                return live
            if time.time() >= deadline:
                if len(live) >= self.base_mesh.dp:
                    return live
                raise ElasticError(
                    f"no quorum after {grace:.1f}s: live={live!r}, need at "
                    f"least one full pod of dp={self.base_mesh.dp}")
            time.sleep(min(e.heartbeat_s, 0.2))

    # ------------------------------------------------------------ the loop
    def _epoch_spec(self, mesh):
        resume = False
        if self.spec.ckpt.dir:
            from ..checkpoint.ckpt import latest_step
            resume = latest_step(self.spec.ckpt.dir) is not None
        return dataclasses.replace(
            self.spec, mesh=mesh,
            ckpt=dataclasses.replace(self.spec.ckpt, resume=resume))

    def _event(self, old_spec, new_spec, step, live, drain_s):
        from ..api import build
        # topologies as (pods, dp) — the cascade's two-level split
        ev = {"step": step, "live": list(live),
              "old_topology": [old_spec.mesh.pods, old_spec.mesh.dp],
              "new_topology": [new_spec.mesh.pods, new_spec.mesh.dp],
              "n": new_spec.mesh.pods * new_spec.mesh.dp,
              "n1": new_spec.mesh.dp,
              "drain_s": round(drain_s, 3),
              "bytes_on_wire": build.modeled_bytes_on_wire(new_spec),
              "time_on_wire": build.modeled_time_on_wire(new_spec)}
        return ev

    def run(self, n_steps: int | None = None) -> list:
        from ..api.callbacks import default_callbacks
        from ..api.session import TrainSession
        e = self.spec.elastic
        live = self.wait_for_quorum()
        mesh = derive_topology(len(live), self.base_mesh)
        while True:
            spec_i = self._epoch_spec(mesh)
            monitor = MembershipMonitor(self.membership, self.base_mesh,
                                        heartbeat_s=e.heartbeat_s)
            monitor.live = live
            # monitor FIRST: it must see the step before PeriodicCheckpoint
            # decides whether this is a stop-step worth persisting
            cbs = ([monitor]
                   + default_callbacks(spec_i, membership=self.membership)
                   + self.user_callbacks)
            inner = TrainSession(spec_i, callbacks=cbs)
            self.session = inner
            self.history += inner.run(n_steps)
            if monitor.fatal is not None:
                raise monitor.fatal
            done = inner.step >= self.spec.steps or not monitor.changed
            if done:
                return self.history
            # topology changed mid-run: re-derive from the CURRENT live
            # set (it may have shifted again while the epoch drained)
            live = self.membership.live()
            new_mesh = derive_topology(len(live), self.base_mesh)
            drain_s = (time.time() - monitor.detected_at
                       if monitor.detected_at else 0.0)
            new_spec = self._epoch_spec(new_mesh)
            ev = self._event(spec_i, new_spec, inner.step, live, drain_s)
            self.events.append(ev)
            print(f"membership change at step {monitor.detected_step}: "
                  f"{ev['old_topology']} -> {ev['new_topology']} "
                  f"(live={ev['live']})", flush=True)
            for cb in self.user_callbacks:
                cb.on_membership_change(spec_i.mesh, new_mesh, inner.step)
            mesh = new_mesh
