"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * n_links * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (whole-program,
all chips -> divide by chip count). collective_bytes is parsed from the
compiled HLO text: we sum the result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute, bucketed by
replica-group size so cross-pod traffic is visible separately.
"""
from __future__ import annotations

import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
N_LINKS = 4                  # links usable concurrently per chip (2D torus)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-kind, per-group-size result bytes (whole program, per device)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes = m.group(1) or m.group(2)
        kind = m.group(3)
        nbytes = _shape_bytes(shapes)
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = len(gm.group(1).split(","))
        else:
            gm2 = _GROUPS_V2_RE.search(line)
            gsize = int(gm2.group(2)) if gm2 else 0
        key = f"{kind}/g{gsize}"
        rec = out.setdefault(key, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def collective_wire_bytes(colls: dict) -> int:
    """Approximate per-device wire bytes: result-shape bytes scaled by the
    ring-algorithm factor (N-1)/N per op kind."""
    total = 0
    for key, rec in colls.items():
        kind, g = key.split("/g")
        g = max(int(g), 1)
        factor = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            factor *= 2.0        # reduce-scatter + all-gather
        total += rec["bytes"] * factor
    return int(total)


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, chips: int) -> dict:
    """cost_analysis numbers are whole-program per-device already (SPMD)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / (N_LINKS * LINK_BW)
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}
