"""shard_map step builders: train / prefill / decode.

This is where the paper's technique becomes a first-class runtime feature:
``make_train_step(..., sync)`` selects how the data-parallel gradient
synchronization is executed — XLA psum, a faithful ring all-reduce, or the
OptINC quantize->integer-reduce->Q(mean) collective (core.collective).

With FSDP, gradients of weight-sharded parameters are already
reduce-scattered over 'data' by the all-gather transpose; the remaining
explicit sync (and OptINC's target) is the cross-pod axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.collective import SyncConfig, sync_gradients
from ..models import lm
from ..models.config import ModelConfig
from ..models.layers import ShardCtx
from ..optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm


def make_ctx(mesh, fsdp: bool = False, seq_shard_cache: bool = False,
             seq_parallel: bool = False, remat_groups: int = 0) -> ShardCtx:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ShardCtx(tp=sizes.get("model", 1), dp=sizes.get("data", 1),
                    pods=sizes.get("pod", 1), fsdp=fsdp,
                    seq_shard_cache=seq_shard_cache,
                    seq_parallel=seq_parallel, remat_groups=remat_groups)


def batch_specs(ctx: ShardCtx, cfg: ModelConfig, batch_shardable: bool = True):
    dp = ctx.dp_axes if batch_shardable else None
    spec = {"tokens": P(dp, None)}
    if cfg.enc_dec:
        spec["enc_frames"] = P(dp, None, None)
    return spec


def _fsdp_leaf_tree(specs, ctx: ShardCtx):
    """True for every param leaf whose spec includes the data axis (its
    gradient is already reduce-scattered over 'data' by AD)."""
    def has_data(spec):
        return ctx.data_axis in [a for a in spec if a is not None]
    return jax.tree.map(has_data, specs,
                        is_leaf=lambda x: isinstance(x, P))


def _split_sync(grads, fsdp_mask, ctx, sync: SyncConfig, key, residual):
    """Sync replicated-leaf grads over the full DP axes; FSDP-sharded leaf
    grads only over the pod axis (and rescale the AD sum to a mean)."""
    leaves, treedef = jax.tree.flatten(grads)
    masks = jax.tree.leaves(fsdp_mask)
    res_leaves = (jax.tree.leaves(residual) if residual is not None
                  else [None] * len(leaves))
    rep_axes = ctx.dp_axes
    pod_axes = (ctx.pod_axis,) if ctx.pods > 1 else ()
    out, new_res = [], []
    rep_idx = [i for i, m in enumerate(masks) if not m]
    # replicated leaves: the full OptINC/ring/psum sync
    rep_tree = [leaves[i] for i in rep_idx]
    rep_res = [res_leaves[i] for i in rep_idx]
    rep_res = rep_res if residual is not None else None
    synced_rep, res_rep = sync_gradients(
        rep_tree, dataclasses.replace(sync, axes=rep_axes), key, rep_res)
    # fsdp leaves: AD already summed over 'data' -> mean; sync pods
    it = iter(synced_rep)
    it_res = iter(res_rep) if res_rep is not None else None
    for i, (g, m) in enumerate(zip(leaves, masks)):
        if not m:
            out.append(next(it))
            new_res.append(next(it_res) if it_res is not None else None)
            continue
        g = g / ctx.dp
        if pod_axes:
            g_s, r_s = sync_gradients(
                [g], dataclasses.replace(sync, axes=pod_axes), key, None)
            g = g_s[0]
        out.append(g)
        new_res.append(jnp.zeros((1,), jnp.float32) if residual is not None
                       else None)
    grads = jax.tree.unflatten(treedef, out)
    res = (jax.tree.unflatten(treedef, new_res)
           if residual is not None else None)
    return grads, res


def make_train_step(cfg: ModelConfig, mesh, sync: SyncConfig,
                    opt: AdamWConfig, fsdp: bool = False,
                    error_feedback: bool = False,
                    seq_parallel: bool = False, remat_groups: int = 0):
    """Returns (step_fn, in_specs, out_specs). step_fn is shard_map'd but
    NOT jit'd (callers jit / lower it)."""
    assert not (seq_parallel and cfg.enc_dec), "SP not wired for enc-dec"
    ctx = make_ctx(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                   remat_groups=remat_groups)
    specs = lm.flat_specs(cfg, ctx)
    fsdp_mask = _fsdp_leaf_tree(specs, ctx)
    bspec = batch_specs(ctx, cfg)

    def step(params, opt_state, batch, key):
        def lf(p):
            return lm.loss_fn(cfg, ctx, p, batch)
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, _ = _split_sync(grads, fsdp_mask, ctx, sync, key, None)
        grads, gnorm = clip_by_global_norm(
            grads, opt.clip_norm, axis_names=(ctx.model_axis,))
        params, opt_state = adamw_update(opt, params, grads, opt_state)
        metrics = {"loss": lax.pmean(loss, ctx.dp_axes),
                   "grad_norm": gnorm}
        return params, opt_state, metrics

    in_specs = (specs, opt_specs(specs), bspec, P())
    out_specs = (specs, opt_specs(specs), {"loss": P(), "grad_norm": P()})
    fn = jax.shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def opt_specs(param_specs_tree):
    return {"m": param_specs_tree, "v": param_specs_tree, "step": P()}


def make_prefill_step(cfg: ModelConfig, mesh, fsdp: bool = False,
                      seq_parallel: bool = False, remat_groups: int = 0):
    assert not (seq_parallel and cfg.enc_dec), "SP not wired for enc-dec"
    ctx = make_ctx(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                   remat_groups=remat_groups)
    specs = lm.flat_specs(cfg, ctx)
    bspec = batch_specs(ctx, cfg)

    def step(params, batch):
        return lm.prefill_step(cfg, ctx, params, batch["tokens"],
                               batch.get("enc_frames"))

    cache_spec = cache_specs(cfg, ctx)
    out_specs = (P(ctx.dp_axes, "model"), cache_spec)
    fn = jax.shard_map(step, mesh=mesh, in_specs=(specs, bspec),
                       out_specs=out_specs, check_vma=False)
    return fn, (specs, bspec), out_specs


def make_decode_step(cfg: ModelConfig, mesh, fsdp: bool = False,
                     seq_shard_cache: bool = False,
                     batch_shardable: bool = True):
    ctx = make_ctx(mesh, fsdp=fsdp, seq_shard_cache=seq_shard_cache)
    specs = lm.flat_specs(cfg, ctx)
    dp = ctx.dp_axes if batch_shardable else None

    def step(params, cache, token, pos):
        return lm.decode_step(cfg, ctx, params, cache, token, pos)

    cache_spec = cache_specs(cfg, ctx, batch_shardable=batch_shardable)
    in_specs = (specs, cache_spec, P(dp, None), P())
    out_specs = (P(dp, "model"), cache_spec)
    fn = jax.shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, batch_shardable: bool = True):
    """PartitionSpec tree matching lm.init_cache's structure: batch over the
    DP axes (when shardable), heads over 'model', optionally cache sequence
    over 'data' (flash-decode sequence sharding)."""
    dp = ctx.dp_axes if batch_shardable else None
    seq_ax = ctx.data_axis if ctx.seq_shard_cache else None

    def kv():
        return {"k": P(None, dp, ctx.model_axis, seq_ax, None),
                "v": P(None, dp, ctx.model_axis, seq_ax, None)}

    if cfg.ssm == "mamba2":
        out = {"mamba": {
            "ssm": P(None, dp, ctx.model_axis, None, None),
            "conv_x": P(None, dp, None, ctx.model_axis),
            "conv_bc": P(None, dp, None, None)}}
        if cfg.attn_every:
            out["attn"] = kv()
        return out
    if cfg.ssm == "xlstm":
        st = P(None, dp, ctx.model_axis, None)
        out = {"mlstm": {"c": P(None, dp, ctx.model_axis, None, None),
                         "n": st}}
        if cfg.slstm_every:
            out["slstm"] = {"h": st, "c": st, "n": st, "m": st}
        return out
    if cfg.enc_dec:
        return {"self": kv(), "cross": kv()}
    if cfg.moe and cfg.mla:
        def mla():
            return {"ckv": P(None, dp, seq_ax, None),
                    "scale": P(None, dp, seq_ax, None),
                    "krope": P(None, dp, seq_ax, None)}
        out = {"moe": mla()}
        if cfg.first_dense_layers:
            out["dense"] = mla()
        return out
    if cfg.moe:
        out = {"moe": kv()}
        if cfg.first_dense_layers:
            out["dense"] = kv()
        return out
    return {"layers": kv()}
