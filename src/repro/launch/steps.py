"""shard_map step builders: train / prefill / decode.

This is where the paper's technique becomes a first-class runtime feature:
``make_train_step(..., sync)`` selects how the data-parallel gradient
synchronization is executed — any backend registered with the bucket-fused
collective engine (repro.collectives): XLA psum, a faithful ring
all-reduce, the OptINC quantize->integer-reduce->Q(mean) collective, or
the two-level carry-cascade over a (pod, data) mesh.

With FSDP, gradients of weight-sharded parameters are already
reduce-scattered over 'data' by the all-gather transpose; the remaining
explicit sync (and OptINC's target) is the cross-pod axis.  The
replicated and FSDP-sharded leaf groups are bucketed separately so each
group issues O(ceil(bytes / bucket_bytes)) collective launches per step.
With ``SyncConfig.overlap`` those launches stream in gradient-readiness
order (``grad_readiness``): a bucket's collective depends only on the
leaves it fuses, so the optical fabric starts reducing the deepest
layers' gradients while the shallower layers are still differentiating.

Error-feedback residuals are explicit step state: ``step`` takes and
returns a ``sync_state`` dict ({} when feedback is off, otherwise
device-local f32 residual vectors for the two leaf groups), so the
quantization error genuinely carries across steps.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat  # noqa: F401  (jax API shims)
from ..collectives import SyncConfig, residual_size, sync_gradients
from ..models import lm
from ..models.config import ModelConfig
from ..models.layers import ShardCtx
from ..optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm


def make_ctx(mesh, fsdp: bool = False, seq_shard_cache: bool = False,
             seq_parallel: bool = False, remat_groups: int = 0) -> ShardCtx:
    """ShardCtx for an existing mesh — delegates to repro.api.MeshSpec,
    the single place ShardCtx derivation lives."""
    from ..api.spec import MeshSpec  # lazy: repro.api imports this module
    return MeshSpec.from_mesh(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                              remat_groups=remat_groups
                              ).ctx(seq_shard_cache=seq_shard_cache)


def batch_specs(ctx: ShardCtx, cfg: ModelConfig, batch_shardable: bool = True):
    dp = ctx.dp_axes if batch_shardable else None
    spec = {"tokens": P(dp, None)}
    if cfg.enc_dec:
        spec["enc_frames"] = P(dp, None, None)
    return spec


def _fsdp_leaf_tree(specs, ctx: ShardCtx):
    """True for every param leaf whose spec includes the data axis (its
    gradient is already reduce-scattered over 'data' by AD)."""
    def has_data(spec):
        return ctx.data_axis in [a for a in spec if a is not None]
    return jax.tree.map(has_data, specs,
                        is_leaf=lambda x: isinstance(x, P))


def _group_sync(group, sync: SyncConfig, key, residual, readiness=None):
    """Sync one leaf group through the bucketed engine, always returning a
    residual vector of stable shape when error feedback is on (exact
    backends yield no quantization error -> zeros)."""
    if not group:
        return [], (jnp.zeros((0,), jnp.float32) if sync.error_feedback
                    else None)
    synced, new_res = sync_gradients(group, sync, key, residual,
                                     readiness=readiness)
    if sync.error_feedback and new_res is None:
        new_res = jnp.zeros((residual_size(group),), jnp.float32)
    return synced, new_res


def grad_readiness(global_indices, n_leaves: int) -> tuple:
    """Per-leaf gradient emission ranks for a leaf group (lower = that
    gradient leaves the backward earlier).  Backward differentiates the
    network back to front, so the LAST leaf of the (forward-ordered)
    param tree is ready first: leaf i is ready at rank n_leaves - 1 - i.
    This is the readiness model the streaming engine's ``launch_order``
    consumes; ranks are computed from GLOBAL leaf indices so the two
    leaf groups of ``_split_sync`` schedule against the same backward."""
    return tuple(n_leaves - 1 - i for i in global_indices)


def _split_sync(grads, fsdp_mask, ctx, sync: SyncConfig, key, sync_state):
    """Sync replicated-leaf grads over the full DP axes; FSDP-sharded leaf
    grads only over the pod axis (and rescale the AD sum to a mean).

    Each group is fused into fixed-size buckets before the collective, so
    the launch count is O(buckets), not O(leaves).  With ``sync.overlap``
    each group's buckets dispatch in gradient-readiness order
    (``grad_readiness``) instead of behind a full-pytree barrier.
    Returns ``(synced_grads, new_sync_state)``; ``sync_state`` carries
    the two groups' error-feedback residual vectors ({} when feedback is
    off).
    """
    leaves, treedef = jax.tree.flatten(grads)
    masks = jax.tree.leaves(fsdp_mask)
    rep_axes = ctx.dp_axes
    pod_axes = (ctx.pod_axis,) if ctx.pods > 1 else ()
    ef = sync.error_feedback
    sync_state = sync_state or {}
    k_rep = k_fs = None
    if key is not None:
        k_rep, k_fs = jax.random.split(key)
    rep_idx = [i for i, m in enumerate(masks) if not m]
    fs_idx = [i for i, m in enumerate(masks) if m]
    # replicated leaves: the full sync over (pod,) + data axes
    synced_rep, rep_res = _group_sync(
        [leaves[i] for i in rep_idx],
        dataclasses.replace(sync, axes=rep_axes),
        k_rep, sync_state.get("rep") if ef else None,
        readiness=grad_readiness(rep_idx, len(leaves)))
    # fsdp leaves: AD already reduce-scattered (summed) over 'data' ->
    # rescale to a mean, then sync the remaining cross-pod level.  That
    # single level is exactly a one-level OptINC, so cascade mode (which
    # needs two axes) degrades to optinc here.
    fs = [leaves[i] / ctx.dp for i in fs_idx]
    if pod_axes and fs:
        pod_mode = "optinc" if sync.mode == "cascade" else sync.mode
        synced_fs, fs_res = _group_sync(
            fs, dataclasses.replace(sync, axes=pod_axes, mode=pod_mode),
            k_fs, sync_state.get("fsdp") if ef else None,
            readiness=grad_readiness(fs_idx, len(leaves)))
    else:
        synced_fs = fs
        fs_res = (jnp.zeros((residual_size(fs),), jnp.float32) if ef
                  else None)
    out = [None] * len(leaves)
    for i, g in zip(rep_idx, synced_rep):
        out[i] = g
    for i, g in zip(fs_idx, synced_fs):
        out[i] = g
    grads = jax.tree.unflatten(treedef, out)
    new_state = {"rep": rep_res, "fsdp": fs_res} if ef else {}
    return grads, new_state


def sync_state_specs(mesh, sync: SyncConfig):
    """PartitionSpec tree for the error-feedback sync_state: each device
    owns its own residual slice, so the vectors are sharded over EVERY
    mesh axis along dim 0 ({} when feedback is off)."""
    if not sync.error_feedback:
        return {}
    all_axes = tuple(mesh.axis_names)
    return {"rep": P(all_axes), "fsdp": P(all_axes)}


def _local_leaf_sizes(cfg: ModelConfig, ctx: ShardCtx, mesh):
    """(sizes, masks): per-leaf LOCAL (inside-shard_map) element counts and
    the fsdp mask, in flat_specs leaf order."""
    specs = lm.flat_specs(cfg, ctx)
    p_sds = lm.param_shape_dtype(cfg, ctx)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    is_p = lambda x: isinstance(x, P)
    spec_leaves = jax.tree.leaves(specs, is_leaf=is_p)
    sds_leaves = jax.tree.leaves(p_sds)
    masks = jax.tree.leaves(_fsdp_leaf_tree(specs, ctx))
    sizes = []
    for sds, spec in zip(sds_leaves, spec_leaves):
        n = int(sds.size)
        for entry in spec:
            for ax in ((entry,) if not isinstance(entry, tuple) else entry):
                if ax is not None:
                    n //= mesh_sizes[ax]
        sizes.append(n)
    return sizes, masks


def init_sync_state(cfg: ModelConfig, mesh, sync: SyncConfig,
                    fsdp: bool = False, error_feedback: bool = False,
                    seq_parallel: bool = False, remat_groups: int = 0):
    """Zero-initialized global sync_state matching ``sync_state_specs``.

    Residuals are per-device local quantization error, so the global
    arrays are (n_devices * local_group_size,) f32 vectors.  They are
    checkpointed alongside params/opt (``CheckpointManager.save``'s
    ``sync_state`` with the ``sync_state_specs`` sharding), so a resumed
    run restores them bit-exactly.  ``error_feedback`` merges into
    ``sync`` exactly as in ``make_train_step`` so the two calls always
    agree on the state structure.
    """
    if not (sync.error_feedback or error_feedback):
        return {}
    ctx = make_ctx(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                   remat_groups=remat_groups)
    sizes, masks = _local_leaf_sizes(cfg, ctx, mesh)
    rep = sum(s for s, m in zip(sizes, masks) if not m)
    fs = sum(s for s, m in zip(sizes, masks) if m)
    ndev = int(mesh.devices.size)
    return {"rep": jnp.zeros((ndev * rep,), jnp.float32),
            "fsdp": jnp.zeros((ndev * fs,), jnp.float32)}


def make_train_step(cfg: ModelConfig, mesh, sync: SyncConfig,
                    opt: AdamWConfig, fsdp: bool = False,
                    error_feedback: bool = False,
                    seq_parallel: bool = False, remat_groups: int = 0):
    """Returns (step_fn, in_specs, out_specs). step_fn is shard_map'd but
    NOT jit'd (callers jit / lower it).

    step(params, opt_state, sync_state, batch, key) ->
        (params, opt_state, sync_state, metrics)
    where sync_state is {} unless error feedback is on (init_sync_state).
    """
    assert not (seq_parallel and cfg.enc_dec), "SP not wired for enc-dec"
    sync = dataclasses.replace(
        sync, error_feedback=sync.error_feedback or error_feedback)
    ctx = make_ctx(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                   remat_groups=remat_groups)
    specs = lm.flat_specs(cfg, ctx)
    fsdp_mask = _fsdp_leaf_tree(specs, ctx)
    bspec = batch_specs(ctx, cfg)
    sspec = sync_state_specs(mesh, sync)

    def step(params, opt_state, sync_state, batch, key):
        def lf(p):
            return lm.loss_fn(cfg, ctx, p, batch)
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, sync_state = _split_sync(grads, fsdp_mask, ctx, sync, key,
                                        sync_state)
        grads, gnorm = clip_by_global_norm(
            grads, opt.clip_norm, axis_names=(ctx.model_axis,))
        params, opt_state = adamw_update(opt, params, grads, opt_state)
        metrics = {"loss": lax.pmean(loss, ctx.dp_axes),
                   "grad_norm": gnorm}
        return params, opt_state, sync_state, metrics

    in_specs = (specs, opt_specs(specs), sspec, bspec, P())
    out_specs = (specs, opt_specs(specs), sspec,
                 {"loss": P(), "grad_norm": P()})
    fn = jax.shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def opt_specs(param_specs_tree):
    return {"m": param_specs_tree, "v": param_specs_tree, "step": P()}


def make_prefill_step(cfg: ModelConfig, mesh, fsdp: bool = False,
                      seq_parallel: bool = False, remat_groups: int = 0):
    assert not (seq_parallel and cfg.enc_dec), "SP not wired for enc-dec"
    ctx = make_ctx(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                   remat_groups=remat_groups)
    specs = lm.flat_specs(cfg, ctx)
    bspec = batch_specs(ctx, cfg)

    def step(params, batch):
        return lm.prefill_step(cfg, ctx, params, batch["tokens"],
                               batch.get("enc_frames"))

    cache_spec = cache_specs(cfg, ctx)
    out_specs = (P(ctx.dp_axes, "model"), cache_spec)
    fn = jax.shard_map(step, mesh=mesh, in_specs=(specs, bspec),
                       out_specs=out_specs, check_vma=False)
    return fn, (specs, bspec), out_specs


def make_batched_prefill_step(cfg: ModelConfig, mesh, fsdp: bool = False):
    """Serving prefill over a packed (b, t) prompt batch with per-row
    valid lengths (lm.batched_prefill_step) — rows shard over the DP
    axes, so dp > 1 serving meshes keep their data axis busy during
    prefill (the decode step stays replicated over 'data')."""
    ctx = make_ctx(mesh, fsdp=fsdp)
    specs = lm.flat_specs(cfg, ctx)

    def step(params, tokens, lengths):
        return lm.batched_prefill_step(cfg, ctx, params, tokens, lengths)

    cache_spec = cache_specs(cfg, ctx)
    in_specs = (specs, P(ctx.dp_axes, None), P(ctx.dp_axes))
    out_specs = (P(ctx.dp_axes, "model"), cache_spec)
    fn = jax.shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def make_decode_step(cfg: ModelConfig, mesh, fsdp: bool = False,
                     seq_shard_cache: bool = False,
                     batch_shardable: bool = True):
    ctx = make_ctx(mesh, fsdp=fsdp, seq_shard_cache=seq_shard_cache)
    specs = lm.flat_specs(cfg, ctx)
    dp = ctx.dp_axes if batch_shardable else None

    def step(params, cache, token, pos):
        return lm.decode_step(cfg, ctx, params, cache, token, pos)

    cache_spec = cache_specs(cfg, ctx, batch_shardable=batch_shardable)
    in_specs = (specs, cache_spec, P(dp, None), P())
    out_specs = (P(dp, "model"), cache_spec)
    fn = jax.shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def cache_specs(cfg: ModelConfig, ctx: ShardCtx, batch_shardable: bool = True):
    """PartitionSpec tree matching lm.init_cache's structure: batch over the
    DP axes (when shardable), heads over 'model', optionally cache sequence
    over 'data' (flash-decode sequence sharding)."""
    dp = ctx.dp_axes if batch_shardable else None
    seq_ax = ctx.data_axis if ctx.seq_shard_cache else None

    def kv():
        return {"k": P(None, dp, ctx.model_axis, seq_ax, None),
                "v": P(None, dp, ctx.model_axis, seq_ax, None)}

    if cfg.ssm == "mamba2":
        out = {"mamba": {
            "ssm": P(None, dp, ctx.model_axis, None, None),
            "conv_x": P(None, dp, None, ctx.model_axis),
            "conv_bc": P(None, dp, None, None)}}
        if cfg.attn_every:
            out["attn"] = kv()
        return out
    if cfg.ssm == "xlstm":
        st = P(None, dp, ctx.model_axis, None)
        out = {"mlstm": {"c": P(None, dp, ctx.model_axis, None, None),
                         "n": st}}
        if cfg.slstm_every:
            out["slstm"] = {"h": st, "c": st, "n": st, "m": st}
        return out
    if cfg.enc_dec:
        return {"self": kv(), "cross": kv()}
    if cfg.moe and cfg.mla:
        def mla():
            return {"ckv": P(None, dp, seq_ax, None),
                    "scale": P(None, dp, seq_ax, None),
                    "krope": P(None, dp, seq_ax, None)}
        out = {"moe": mla()}
        if cfg.first_dense_layers:
            out["dense"] = mla()
        return out
    if cfg.moe:
        out = {"moe": kv()}
        if cfg.first_dense_layers:
            out["dense"] = kv()
        return out
    return {"layers": kv()}
