"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_405b \
      --shape train_4k [--multi-pod] [--sync optinc|ring|psum|cascade] \
      [--fsdp auto|on|off] [--out results/dryrun]

Each invocation compiles ONE cell in a fresh process (512 host devices) and
writes a JSON record with memory_analysis, cost_analysis, and the parsed
collective table for the roofline (§Roofline in EXPERIMENTS.md).

The cells are lowered through ``repro.api``: a RunSpec describes the
scenario and ``repro.api.build`` constructs exactly the shard_map programs
``TrainSession`` / ``ServeSession`` run, so the dry-run measures the same
code path serving and training execute.
"""
# XLA_FLAGS must be in the environment before jax initializes its backend;
# keep this mutation ahead of every jax (or repro) import.
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (jax API shims; after XLA_FLAGS)
from repro import configs
from repro.api import MeshSpec, RunSpec, SpecError, SyncConfig, build
from repro.api.shapes import (batch_sds, cache_sds, globalize_cache_sds,
                              opt_sds, sds)
from repro.collectives import available_backends
from repro.launch import roofline
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig

# archs small enough to keep parameters replicated across the data axis
NO_FSDP = {"xlstm-125m", "whisper-tiny", "paper-llama"}


def cell_spec(arch: str, multi_pod: bool, sync_mode: str,
              fsdp_opt: str = "auto", moment_dtype: str = "bfloat16",
              seq_parallel: bool = False, remat_groups: int = 0,
              bucket_bytes: int = 4 * 2 ** 20, seq_len: int = 512,
              global_batch: int = 32) -> RunSpec:
    """The production-mesh RunSpec for one dry-run cell."""
    from repro.api import DataConfig
    cfg = configs.get(arch)
    fsdp = (cfg.name not in NO_FSDP) if fsdp_opt == "auto" else fsdp_opt == "on"
    mesh = MeshSpec(pods=2 if multi_pod else 1, dp=16, tp=16, fsdp=fsdp,
                    seq_parallel=seq_parallel, remat_groups=remat_groups)
    return RunSpec(arch=arch, mesh=mesh,
                   sync=SyncConfig(mode=sync_mode, bucket_bytes=bucket_bytes),
                   optim=AdamWConfig(moment_dtype=moment_dtype),
                   data=DataConfig(vocab=0, seq_len=seq_len,
                                   global_batch=global_batch, seed=0))


def lower_cell(arch: str, shape_name: str, multi_pod: bool, sync_mode: str,
               fsdp_opt: str = "auto", moment_dtype: str = "bfloat16",
               seq_shard_long: bool = True, seq_parallel: bool = False,
               remat_groups: int = 0, bucket_bytes: int = 4 * 2 ** 20):
    from repro.models import lm
    cfg = configs.get(arch)
    cell = configs.cells(arch)[shape_name]
    if "skip" in cell:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": cell["skip"]}
    spec = cell_spec(arch, multi_pod, sync_mode, fsdp_opt, moment_dtype,
                     seq_parallel, remat_groups, bucket_bytes,
                     seq_len=cell["seq_len"], global_batch=cell["global_batch"])
    mesh = spec.mesh.build()
    dp_total = spec.mesh.pods * spec.mesh.dp
    kind = cell["kind"]
    t0 = time.time()

    if kind == "train":
        spec.validate()
        step, _, _ = build.build_train_step(spec, cfg, mesh)
        ctx = spec.mesh.ctx()
        p_sds = lm.param_shape_dtype(cfg, ctx)
        mdt = jnp.bfloat16 if moment_dtype == "bfloat16" else jnp.float32
        args = (p_sds, opt_sds(p_sds, mdt), {},
                batch_sds(cfg, cell["seq_len"], cell["global_batch"]),
                jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    elif kind == "prefill":
        step, _, _ = build.build_prefill_step(spec, cfg, mesh)
        ctx = spec.mesh.ctx()
        p_sds = lm.param_shape_dtype(cfg, ctx)
        args = (p_sds, batch_sds(cfg, cell["seq_len"], cell["global_batch"]))
    else:  # decode
        gb = cell["global_batch"]
        shardable = gb >= dp_total
        seq_shard = (not shardable) and seq_shard_long
        step, _, _ = build.build_decode_step(spec, cfg, mesh,
                                             seq_shard_cache=seq_shard,
                                             batch_shardable=shardable)
        ctx = spec.mesh.ctx(seq_shard_cache=seq_shard)
        p_sds = lm.param_shape_dtype(cfg, ctx)
        b_local = gb // dp_total if shardable else gb
        c_sds = cache_sds(cfg, ctx, b_local, cell["seq_len"])
        cspec = build.decode_cache_specs(spec, cfg, seq_shard_cache=seq_shard,
                                         batch_shardable=shardable)
        c_sds = globalize_cache_sds(c_sds, cspec, mesh)
        args = (p_sds, c_sds, sds((gb, 1), jnp.int32), sds((), jnp.int32))

    # donate params/opt (train) or cache (decode) so memory_analysis
    # reflects in-place updates, as a real training loop would run
    donate = (0, 1) if kind in ("train",) else ((1,) if kind == "decode" else ())
    with jax.set_mesh(mesh):
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = roofline.parse_collectives(hlo)
    chips = mesh.devices.size
    # cost_analysis / memory_analysis report the (single) SPMD per-device
    # program — validated against an analytic matmul; use raw values
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_bytes = roofline.collective_wire_bytes(colls)
    terms = roofline.roofline_terms(flops, bytes_acc, coll_bytes, chips)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind, "sync": sync_mode if kind == "train" else None,
        "fsdp": spec.mesh.fsdp, "seq_parallel": seq_parallel,
        "remat_groups": remat_groups, "chips": chips,
        "run_spec": spec.to_json_dict(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "raw_stats": True,
        "memory": {  # per-device
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collectives": colls,
        "collective_wire_bytes": coll_bytes,
        "roofline": terms,
    }
    return rec


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(configs.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sync", default="optinc",
                    choices=list(available_backends()))
    ap.add_argument("--bucket-mb", type=float, default=4.0)
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--moment-dtype", default="bfloat16")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat-groups", type=int, default=0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    try:
        rec = lower_cell(args.arch, args.shape, args.multi_pod, args.sync,
                         args.fsdp, args.moment_dtype,
                         seq_parallel=args.seq_parallel,
                         remat_groups=args.remat_groups,
                         bucket_bytes=int(args.bucket_mb * 2 ** 20))
    except SpecError as e:
        raise SystemExit(f"error: {e}")
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    tag = (f"{args.arch}.{args.shape}."
           f"{'2x16x16' if args.multi_pod else '16x16'}.{args.sync}"
           f"{'' if args.fsdp == 'auto' else '.' + args.fsdp}"
           f"{'' if args.moment_dtype == 'bfloat16' else '.f32mom'}"
           f"{'.sp' if args.seq_parallel else ''}"
           f"{('.rg' + str(args.remat_groups)) if args.remat_groups else ''}"
           f"{('.' + args.tag) if args.tag else ''}")
    path = out / f"{tag}.json"
    path.write_text(json.dumps(rec, indent=1))
    if rec.get("skipped"):
        print(f"SKIP {tag}: {rec['skipped']}")
    else:
        r = rec["roofline"]
        print(f"OK {tag}: compile={rec['compile_s']}s "
              f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']}")


if __name__ == "__main__":
    main()
