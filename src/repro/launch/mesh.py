"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — 'pod' is the
cross-pod data-parallel axis whose gradient synchronization OptINC targets
(and the level-2 axis of the cascade sync mode).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
jax-version differences (AxisType, jax.shard_map, jax.set_mesh) are
absorbed by repro.compat.
"""
from __future__ import annotations

from .. import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (smoke tests use (1, 1) or (2, 2))."""
    return compat.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
