"""End-to-end training driver — a thin client of ``repro.api``.

  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --sync optinc --steps 200 --global-batch 32 --seq-len 512 \
      --ckpt-dir results/ckpt/paper_llama [--resume] [--error-layers 3,4,5,6]

  # two-level carry-cascade over a (pod=2, data=2, model=1) mesh
  # (requires >= 4 devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=4)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync cascade --mesh 2x1 --bucket-mb 4

  # streaming engine: buckets dispatch in gradient-readiness order so
  # collectives overlap the remaining backward (bit-identical losses to
  # the barrier path — EXPERIMENTS.md §Overlap)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync cascade --mesh 2x1 --overlap

  # hardware-in-the-loop: the MZI mesh emulator computes the averaged
  # gradient inside the jitted step (--fidelity onn uses the dense ONN;
  # bits<=2 resolves the built-in exact identity ONN, wider bit widths
  # need trained params — see repro.photonics.runtime)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync optinc --bits 2 --fidelity mesh

  # same, with the emulator's rotation layers fused into one Pallas
  # VMEM kernel per batch tile (compiled on TPU, interpreted elsewhere)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync optinc --bits 2 --fidelity mesh \
      --mesh-backend pallas

  # two-level photonic cascade: BOTH reduction levels run the mesh
  # emulator, the eq.-10 carry symbol threaded between them (bit-exact
  # vs --fidelity behavioral on the built-in exact ONN at bits<=2)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync cascade --mesh 2x1 --bits 2 --fidelity mesh

  # thermal drift + shot noise on the emulated mesh (PhaseNoise model,
  # seeded from the per-step key: reproducible, identical across hosts)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync optinc --bits 2 --fidelity mesh \
      --theta-drift-std 0.02 --shot-noise-std 0.01

  # elastic membership: world size becomes a runtime property — the run
  # watches the member registry, re-derives the cascade topology when a
  # pod drops/joins, and reshard-resumes from the last checkpoint
  # (multi-process agents: python -m repro.elastic.worker)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync cascade --mesh 2x1 --elastic \
      --ckpt-dir results/ckpt/elastic --ckpt-every 1

  # resume a checkpoint on a DIFFERENT mesh shape (compatible-reshard:
  # global state re-placed, error-feedback residuals re-bucketized)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync cascade --mesh 2x1 --pods 1 \
      --ckpt-dir results/ckpt/elastic --resume --allow-reshard

  # or describe the whole scenario declaratively:
  PYTHONPATH=src python -m repro.launch.train --spec my_run.json

Every flag is a RunSpec field override (``RunSpec.from_args``); the run
itself — mesh/ShardCtx derivation, init-or-resume, the jitted step loop,
JSONL logging, periodic + SIGTERM-safe checkpointing (params, optimizer,
AND error-feedback residuals), straggler watchdog — lives in
``repro.api.TrainSession``.  ``--resume`` validates the checkpointed
RunSpec against this one and restores bit-exactly.
"""
from __future__ import annotations

import sys

from repro.api import RunSpec, SpecError, TrainSession


def main(argv=None):
    try:
        spec = RunSpec.from_args(argv, description=__doc__)
        if spec.elastic.enabled:
            from repro.elastic import ElasticTrainSession, Membership
            # Single-process elastic run: this process owns the whole
            # mesh, so it self-hosts the registry — one member per rank,
            # all beating from here.  The world forms immediately;
            # membership changes come from suspect tombstones (watchdog
            # --evict-after escalation, or an operator touching
            # <member>.suspect) or from extra agents joining the dir.
            # Multi-process runs use repro.elastic.worker instead, where
            # each process is ONE member and SIGKILL = going stale.
            e = spec.elastic
            ranks = [Membership(e.members_dir(spec.ckpt.dir),
                                member=f"w{i}", heartbeat_s=e.heartbeat_s,
                                timeout_s=e.timeout_s)
                     for i in range(spec.mesh.pods * spec.mesh.dp)]
            for m in ranks:
                m.join()
                m.start_heartbeat()
            try:
                ElasticTrainSession(spec, membership=ranks[0]).run()
            finally:
                for m in ranks:
                    m.stop_heartbeat()
        else:
            TrainSession(spec).run()
    except SpecError as e:
        raise SystemExit(f"error: {e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
