"""End-to-end training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --sync optinc --steps 200 --global-batch 32 --seq-len 512 \
      --ckpt-dir results/ckpt/paper_llama [--resume] [--error-layers 3,4,5,6]

  # two-level carry-cascade over a (pod=2, data=2, model=1) mesh
  # (requires >= 4 devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=4)
  PYTHONPATH=src python -m repro.launch.train --arch paper_llama \
      --smoke-config --sync cascade --mesh 2x1 --bucket-mb 4

Fault tolerance:
  * SIGTERM/SIGINT force a final checkpoint before exit (preemption safe)
  * --resume restarts from the newest valid checkpoint (corrupt ones are
    skipped by manifest validation)
  * the data pipeline is deterministic-by-step, so the resumed run sees
    exactly the tokens it would have seen
  * a step-time watchdog logs straggler steps (> watchdog x median)
"""
from __future__ import annotations

import argparse
import json
import signal
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat  # noqa: F401  (jax API shims: set_mesh et al.)
from repro import configs
from repro.checkpoint import CheckpointManager, load_checkpoint
from repro.checkpoint.ckpt import latest_step
from repro.collectives import SyncConfig, available_backends
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.steps import (init_sync_state, make_ctx, make_train_step,
                                opt_specs)
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_llama")
    ap.add_argument("--smoke-config", action="store_true",
                    help="use the arch's reduced SMOKE config")
    ap.add_argument("--sync", default="optinc",
                    choices=list(available_backends()))
    ap.add_argument("--bucket-mb", type=float, default=4.0,
                    help="fused gradient-bucket size in MiB (collective "
                         "launches per step scale as total_bytes/bucket)")
    ap.add_argument("--pods", type=int, default=0,
                    help="pod (level-2) axis size; 0 = auto (2 for "
                         "--sync cascade, else 1)")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--error-layers", default="",
                    help="Table II key, e.g. '3,4,5,6' (injects ONN errors)")
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1", help="DPxTP, e.g. 4x1")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--watchdog", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default="")
    args = ap.parse_args(argv)

    dp, tp = (int(x) for x in args.mesh.split("x"))
    pods = args.pods or (2 if args.sync == "cascade" else 1)
    if pods > 1:
        # cascade's level-2 axis: (pod, data, model) mesh
        mesh = make_mesh((pods, dp, tp), ("pod", "data", "model"))
    else:
        mesh = make_mesh((dp, tp), ("data", "model"))
    cfg = configs.get_smoke(args.arch) if args.smoke_config else configs.get(args.arch)
    err = tuple(int(x) for x in args.error_layers.split(",")) if args.error_layers else ()
    sync = SyncConfig(mode=args.sync, axes=("data",), bits=args.bits,
                      block=2048, error_layers=err,
                      error_feedback=args.error_feedback,
                      bucket_bytes=int(args.bucket_mb * 2 ** 20))
    opt_cfg = AdamWConfig(lr=args.lr)
    ctx = make_ctx(mesh)

    params = lm.init_params(cfg, ctx, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(opt_cfg, params)
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        s = latest_step(args.ckpt_dir)
        if s is not None:
            specs = {"params": lm.flat_specs(cfg, ctx),
                     "opt": opt_specs(lm.flat_specs(cfg, ctx))}
            tree, man = load_checkpoint(args.ckpt_dir, s,
                                        {"params": params, "opt": opt_state},
                                        mesh=mesh, specs=specs)
            params, opt_state = tree["params"], tree["opt"]
            start = s + 1
            print(f"resumed from step {s}", flush=True)

    step_fn, _, _ = make_train_step(cfg, mesh, sync, opt_cfg)
    sync_state = init_sync_state(cfg, mesh, sync)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.seed)
    ds = SyntheticLM(data)

    stop = {"flag": False}

    def handler(sig, frame):
        print(f"signal {sig}: checkpointing and exiting", flush=True)
        stop["flag"] = True

    signal.signal(signal.SIGTERM, handler)
    signal.signal(signal.SIGINT, handler)

    logf = open(args.log, "a") if args.log else None
    times = []
    key = jax.random.PRNGKey(args.seed + 1)
    with jax.set_mesh(mesh):
        for step in range(start, args.steps):
            t0 = time.time()
            batch = {"tokens": jnp.asarray(ds.batch(step))}
            key, sub = jax.random.split(key)
            params, opt_state, sync_state, metrics = jitted(
                params, opt_state, sync_state, batch, sub)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            med = statistics.median(times[-50:])
            straggler = dt > args.watchdog * med and len(times) > 10
            rec = {"step": step, "loss": round(loss, 5),
                   "time_s": round(dt, 3)}
            if straggler:
                rec["straggler"] = True
            line = json.dumps(rec)
            print(line, flush=True)
            if logf:
                logf.write(line + "\n")
                logf.flush()
            if mgr and ((step + 1) % args.ckpt_every == 0 or stop["flag"]
                        or step == args.steps - 1):
                mgr.save(step, params, opt_state,
                         extra={"arch": cfg.name, "sync": args.sync})
            if stop["flag"]:
                break
    if mgr:
        mgr.wait()
    if logf:
        logf.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
