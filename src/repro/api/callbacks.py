"""TrainSession hook protocol + the built-in callbacks.

What used to be inline loop code in ``launch/train.py`` (JSONL logging,
periodic checkpointing, SIGTERM-safe final save, straggler watchdog) is
now a small callback stack; a scenario adds behavior by appending a
callback, not by forking the driver.

Hooks (all optional — subclass and override what you need):

  on_train_start(session)
  on_step_end(session, record)   # record: mutable per-step dict; callbacks
                                 # may read/annotate it (step, loss, time_s)
  on_train_end(session)

``session.request_stop()`` ends the loop after the current step;
PeriodicCheckpoint treats a requested stop like a final step, so a
SIGTERM'd run always leaves a fresh checkpoint behind.
"""
from __future__ import annotations

import json
import signal
import statistics


class Callback:
    def on_train_start(self, session):
        pass

    def on_step_end(self, session, record: dict):
        pass

    def on_train_end(self, session):
        pass


class StragglerWatchdog(Callback):
    """Annotates records whose step time exceeds ``factor`` x the rolling
    median (straggler detection; keep this BEFORE the logger).

    ``factor <= 0`` disables the watchdog entirely (``--watchdog 0``): no
    timing history is kept and records are never annotated.  A step at or
    under the threshold resets nothing — the rolling window keeps sliding,
    so one straggler does not poison the median for later steps.
    ``n_flagged`` counts the stragglers seen this run.
    """

    def __init__(self, factor: float = 3.0, window: int = 50, warmup: int = 10):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.times = []
        self.n_flagged = 0

    @property
    def enabled(self) -> bool:
        return self.factor > 0

    def on_step_end(self, session, record):
        if not self.enabled:
            return
        dt = record.get("time_s", 0.0)
        self.times.append(dt)
        med = statistics.median(self.times[-self.window:])
        if len(self.times) > self.warmup and dt > self.factor * med:
            record["straggler"] = True
            self.n_flagged += 1


class JsonlLogger(Callback):
    """One JSON line per step to stdout and (optionally) a file."""

    def __init__(self, path: str = "", echo: bool = True):
        self.path = path
        self.echo = echo
        self._f = None

    def on_train_start(self, session):
        if self.path:
            self._f = open(self.path, "a")

    def on_step_end(self, session, record):
        line = json.dumps(record)
        if self.echo:
            print(line, flush=True)
        if self._f:
            self._f.write(line + "\n")
            self._f.flush()

    def on_train_end(self, session):
        if self._f:
            self._f.close()
            self._f = None


class PeriodicCheckpoint(Callback):
    """Save every N steps, on a requested stop, and at the end of every
    run() call (so a partial ``run(n_steps)`` never loses its state)."""

    def __init__(self, every: int = 50):
        self.every = max(1, every)
        self._last_run = None
        self._last_saved = None

    def on_train_start(self, session):
        self._last_run = None

    def on_step_end(self, session, record):
        step = record["step"]
        self._last_run = step
        if session.mgr and ((step + 1) % self.every == 0
                            or session.stop_requested
                            or step == session.spec.steps - 1):
            session.save_checkpoint(step)
            self._last_saved = step

    def on_train_end(self, session):
        if session.mgr:
            if self._last_run is not None and self._last_saved != self._last_run:
                session.save_checkpoint(self._last_run)
                self._last_saved = self._last_run
            session.mgr.wait()


class SigtermHandler(Callback):
    """SIGTERM/SIGINT request a stop (and thus a final checkpoint) instead
    of killing the loop mid-step — preemption safe.  Handlers are restored
    on train end."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self._previous = {}

    def on_train_start(self, session):
        def handler(sig, frame):
            print(f"signal {sig}: checkpointing and exiting", flush=True)
            session.request_stop()
        for s in self.signals:
            self._previous[s] = signal.signal(s, handler)

    def on_train_end(self, session):
        for s, prev in self._previous.items():
            signal.signal(s, prev)
        self._previous = {}


def default_callbacks(spec) -> list:
    """The train.py-equivalent stack for a RunSpec."""
    return [StragglerWatchdog(spec.watchdog),
            JsonlLogger(spec.log),
            PeriodicCheckpoint(spec.ckpt.every),
            SigtermHandler()]
