"""TrainSession hook protocol + the built-in callbacks.

What used to be inline loop code in ``launch/train.py`` (JSONL logging,
periodic checkpointing, SIGTERM-safe final save, straggler watchdog) is
now a small callback stack; a scenario adds behavior by appending a
callback, not by forking the driver.

The protocol (all hooks optional — subclass and override what you need):

  on_train_start(session)
  on_step(session, record)       # record: mutable per-step dict; callbacks
                                 # may read/annotate it (step, loss, time_s)
  on_checkpoint(session, step)   # after a checkpoint save is queued
  on_membership_change(old_mesh, new_mesh, step)
                                 # elastic runs: the live topology changed;
                                 # the session is about to reshard-resume
  on_train_end(session)

``on_step_end`` is the legacy name of ``on_step``; the base class keeps
it as a delegating alias so both existing subclasses (which override
``on_step_end``) and existing callers (the session loop, tests invoking
it directly) continue to work unchanged.

``session.request_stop()`` ends the loop after the current step;
PeriodicCheckpoint treats a requested stop like a final step, so a
SIGTERM'd (or membership-interrupted) run always leaves a fresh
checkpoint behind.
"""
from __future__ import annotations

import json
import signal
import statistics


class Callback:
    def on_train_start(self, session):
        pass

    def on_step(self, session, record: dict):
        pass

    def on_step_end(self, session, record: dict):
        # legacy alias: the loop calls on_step_end; new-style callbacks
        # override on_step, old-style ones override this directly
        self.on_step(session, record)

    def on_checkpoint(self, session, step: int):
        pass

    def on_membership_change(self, old_mesh, new_mesh, step: int):
        pass

    def on_train_end(self, session):
        pass


class StragglerWatchdog(Callback):
    """Annotates records whose step time exceeds ``factor`` x the rolling
    median (straggler detection; keep this BEFORE the logger).

    ``factor <= 0`` disables the watchdog entirely (``--watchdog 0``): no
    timing history is kept and records are never annotated.  A step at or
    under the threshold resets nothing — the rolling window keeps sliding,
    so one straggler does not poison the median for later steps.
    ``n_flagged`` counts the stragglers seen this run.

    Escalation (``--evict-after``): with a ``membership`` registry bound,
    ``evict_after`` CONSECUTIVE flags on the same rank report that member
    to the registry as suspect — the elastic session then drains its pod
    at the next membership poll instead of dragging every allreduce at
    straggler speed.  A clean step resets the rank's streak; a suspect is
    reported once (the member re-admits itself by beating again).
    Records may carry an explicit ``record["rank"]``; single-process runs
    default to this watchdog's own ``member`` identity.
    """

    def __init__(self, factor: float = 3.0, window: int = 50,
                 warmup: int = 10, evict_after: int = 0, membership=None,
                 member: str | None = None):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.evict_after = evict_after
        self.membership = membership
        self.member = member
        self.times = []
        self.n_flagged = 0
        self.streaks = {}          # rank -> consecutive flags
        self.suspected = set()     # ranks already reported

    @property
    def enabled(self) -> bool:
        return self.factor > 0

    def on_step(self, session, record):
        if not self.enabled:
            return
        dt = record.get("time_s", 0.0)
        self.times.append(dt)
        med = statistics.median(self.times[-self.window:])
        rank = record.get("rank", self.member)
        if len(self.times) > self.warmup and dt > self.factor * med:
            record["straggler"] = True
            self.n_flagged += 1
            self._escalate(rank, dt, med, record)
        else:
            self.streaks[rank] = 0

    def _escalate(self, rank, dt, med, record):
        if not self.evict_after:
            return
        self.streaks[rank] = self.streaks.get(rank, 0) + 1
        if (self.streaks[rank] >= self.evict_after
                and self.membership is not None
                and rank is not None and rank not in self.suspected):
            self.membership.suspect(
                rank, reason=f"{self.streaks[rank]} consecutive straggler "
                             f"flags (last {dt:.3f}s vs median {med:.3f}s)")
            self.suspected.add(rank)
            record["suspected"] = rank


class JsonlLogger(Callback):
    """One JSON line per step to stdout and (optionally) a file."""

    def __init__(self, path: str = "", echo: bool = True):
        self.path = path
        self.echo = echo
        self._f = None

    def on_train_start(self, session):
        if self.path:
            self._f = open(self.path, "a")

    def on_step(self, session, record):
        line = json.dumps(record)
        if self.echo:
            print(line, flush=True)
        if self._f:
            self._f.write(line + "\n")
            self._f.flush()

    def on_train_end(self, session):
        if self._f:
            self._f.close()
            self._f = None


class PeriodicCheckpoint(Callback):
    """Save every N steps, on a requested stop, and at the end of every
    run() call (so a partial ``run(n_steps)`` never loses its state)."""

    def __init__(self, every: int = 50):
        self.every = max(1, every)
        self._last_run = None
        self._last_saved = None

    def on_train_start(self, session):
        self._last_run = None

    def on_step(self, session, record):
        step = record["step"]
        self._last_run = step
        if session.mgr and ((step + 1) % self.every == 0
                            or session.stop_requested
                            or step == session.spec.steps - 1):
            session.save_checkpoint(step)
            self._last_saved = step

    def on_train_end(self, session):
        if session.mgr:
            if self._last_run is not None and self._last_saved != self._last_run:
                session.save_checkpoint(self._last_run)
                self._last_saved = self._last_run
            session.mgr.wait()


class SigtermHandler(Callback):
    """SIGTERM/SIGINT request a stop (and thus a final checkpoint) instead
    of killing the loop mid-step — preemption safe.  Handlers are restored
    on train end."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self._previous = {}

    def on_train_start(self, session):
        def handler(sig, frame):
            print(f"signal {sig}: checkpointing and exiting", flush=True)
            session.request_stop()
        for s in self.signals:
            self._previous[s] = signal.signal(s, handler)

    def on_train_end(self, session):
        for s, prev in self._previous.items():
            signal.signal(s, prev)
        self._previous = {}


def default_callbacks(spec, membership=None) -> list:
    """The train.py-equivalent stack for a RunSpec.  ``membership`` arms
    the watchdog's suspect-report escalation (elastic runs)."""
    return [StragglerWatchdog(spec.watchdog,
                              evict_after=spec.elastic.evict_after,
                              membership=membership),
            JsonlLogger(spec.log),
            PeriodicCheckpoint(spec.ckpt.every),
            SigtermHandler()]
