"""TrainSession: build -> init-or-resume -> jitted step loop.

Owns everything the old ``launch/train.py`` wired by hand: mesh/ShardCtx
derivation (via MeshSpec), parameter/optimizer/sync-state initialization,
checkpoint resume with RunSpec compatibility validation, the jitted
shard_map step, and a callback stack for logging / checkpointing /
signal handling / straggler detection.

Checkpoints persist the full step state — params, optimizer moments, AND
the error-feedback ``sync_state`` residuals (with their sharding specs) —
plus the RunSpec itself in the manifest, so ``--resume`` restores a run
bit-exactly and refuses specs whose state structure doesn't match.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .. import compat  # noqa: F401  (jax API shims: set_mesh et al.)
from ..checkpoint import CheckpointManager, load_checkpoint
from ..checkpoint.ckpt import latest_step, read_manifest
from ..collectives import (is_packed_residuals, pack_residuals,
                           unpack_residuals)
from ..data import SyntheticLM
from ..models import lm
from ..optim import adamw_init
from . import build
from .callbacks import default_callbacks
from .spec import RunSpec, validate_resume_compat


class TrainSession:
    """One training run of one RunSpec.

    >>> spec = RunSpec(arch="minitron_4b", smoke=True, steps=3)
    >>> session = TrainSession(spec)
    >>> history = session.run()          # list of per-step record dicts
    """

    def __init__(self, spec: RunSpec, callbacks: list | None = None):
        spec.validate()
        self.spec = spec
        self.cfg = spec.model_config()
        self.mesh = spec.mesh.build()
        self.ctx = spec.mesh.ctx()
        self.sync = spec.resolved_sync()
        self.callbacks = (list(callbacks) if callbacks is not None
                          else default_callbacks(spec))
        self.mgr = (CheckpointManager(spec.ckpt.dir, keep=spec.ckpt.keep)
                    if spec.ckpt.dir else None)
        self.data = SyntheticLM(spec.resolved_data())
        self.stop_requested = False
        self.step = 0              # next step to execute
        self.last_record = None

        self.params = lm.init_params(self.cfg, self.ctx,
                                     jax.random.PRNGKey(spec.seed))
        self.opt_state = adamw_init(spec.optim, self.params)
        self.sync_state = build.init_sync_state(spec, self.cfg, self.mesh)
        if spec.ckpt.resume:
            self._maybe_resume()

        build.warmup_photonics(spec)   # onn/mesh fidelity: resolve eagerly
        step_fn, _, _ = build.build_train_step(spec, self.cfg, self.mesh)
        self._jitted = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        # per-step keys are folded from a base key, NOT split sequentially,
        # so a resumed step sees exactly the key the uninterrupted run saw
        self._base_key = jax.random.PRNGKey(spec.seed + 1)

    # ------------------------------------------------------------ control
    def request_stop(self):
        """End the loop after the current step (checkpoint included)."""
        self.stop_requested = True

    def save_checkpoint(self, step: int | None = None):
        """Persist params + optimizer + sync_state + the RunSpec manifest.
        With ``sync.sparse_residuals`` the error-feedback residuals are
        stored block-sparsely (only blocks with nonzero carry)."""
        if self.mgr is None:
            return
        step = (self.step - 1) if step is None else step
        sync_state = self.sync_state
        if self.sync.sparse_residuals and sync_state:
            sync_state = pack_residuals(sync_state)
        self.mgr.save(step, self.params, self.opt_state,
                      sync_state=sync_state,
                      extra={"run_spec": self.spec.to_json_dict(),
                             "arch": self.cfg.name, "sync": self.sync.mode})
        for cb in self.callbacks:
            cb.on_checkpoint(self, step)

    def _maybe_resume(self):
        c = self.spec.ckpt
        s = latest_step(c.dir)
        if s is None:
            return
        man = read_manifest(c.dir, s)
        saved_spec = (man.get("extra") or {}).get("run_spec")
        resharded, saved = False, None
        if saved_spec is not None:
            saved = RunSpec.from_json_dict(saved_spec)
            allow = (self.spec.elastic.allow_reshard
                     or self.spec.elastic.enabled)
            compat = validate_resume_compat(saved, self.spec,
                                            allow_reshard=allow)
            resharded = compat.verdict == "reshardable"
        p_specs, o_specs = build.param_specs(self.spec, self.cfg)
        template = {"params": self.params, "opt": self.opt_state}
        specs = {"params": p_specs, "opt": o_specs}
        sync_paths = [p for p in man["leaves"]
                      if p.split("/", 1)[0] == "sync"]
        # block-sparse residual checkpoints store sync/<name>/{idx,val,
        # shape}; either form restores regardless of the current
        # sparse_residuals flag
        sync_packed = bool(sync_paths) and all(
            p.rsplit("/", 1)[-1] in ("idx", "val", "shape")
            for p in sync_paths)
        # error-feedback residual buckets are sized by device count, so a
        # resharded resume may find them re-bucketized: restore any leaf
        # whose saved shape still matches, re-zero the rest (the carry
        # they held was an intra-step numerical refinement, not model
        # state — EXPERIMENTS.md §Elastic training)
        sync_shapes_ok = self.sync_state and sync_paths and all(
            list((man["leaves"].get(f"sync/{name}") or {}).get("shape", ()))
            == list(v.shape) for name, v in self.sync_state.items())
        if self.sync_state and sync_paths and not sync_packed:
            if sync_shapes_ok or not resharded:
                # exact resumes keep the strict path: a shape mismatch
                # without a mesh change is corruption, and
                # load_checkpoint names the offending leaf
                template["sync"] = self.sync_state
                specs["sync"] = build.sync_state_specs(self.spec, self.mesh)
            else:
                print("resharded resume: error-feedback residual buckets "
                      "changed shape; residuals re-zeroed", flush=True)
        elif self.sync_state and not sync_paths:
            print("checkpoint predates sync_state persistence; "
                  "error-feedback residuals restart from zero", flush=True)
        tree, _ = load_checkpoint(c.dir, s, template, mesh=self.mesh,
                                  specs=specs)
        self.params, self.opt_state = tree["params"], tree["opt"]
        if "sync" in tree:
            self.sync_state = tree["sync"]
        elif self.sync_state and sync_packed:
            try:
                self.sync_state = self._load_packed_sync(c.dir, s)
            except ValueError:
                if not resharded:
                    raise
                print("resharded resume: error-feedback residual buckets "
                      "changed shape; residuals re-zeroed", flush=True)
        self.step = s + 1
        note = ""
        if resharded and saved is not None:
            note = (f" (resharded {saved.mesh.shape} -> "
                    f"{self.spec.mesh.shape}; data pipeline continues at "
                    f"sample offset of step {s + 1})")
        print(f"resumed from step {s}{note}", flush=True)

    def _load_packed_sync(self, direc, step: int) -> dict:
        """Restore block-sparse error-feedback residuals: read the packed
        sync/ subtree (via repro.checkpoint — the session never touches
        the on-disk layout), expand to dense, place with the sync
        sharding."""
        from ..checkpoint.ckpt import read_subtree_arrays

        packed = read_subtree_arrays(direc, step, "sync")
        if not is_packed_residuals(packed):
            raise ValueError(
                f"checkpoint step {step} has a malformed block-sparse "
                f"sync/ subtree (entries: "
                f"{ {k: sorted(v) for k, v in packed.items()} })")
        dense = unpack_residuals(packed)
        specs = build.sync_state_specs(self.spec, self.mesh)
        state = {}
        for name, want in self.sync_state.items():
            got = dense.get(name)
            if got is None or got.shape != want.shape:
                raise ValueError(
                    f"packed sync_state {name!r} does not match the run: "
                    f"checkpoint {None if got is None else got.shape} vs "
                    f"run {want.shape}")
            sharding = jax.sharding.NamedSharding(self.mesh, specs[name])
            state[name] = jax.device_put(jnp.asarray(got), sharding)
        return state

    # ------------------------------------------------------------ the loop
    def run_step(self, step: int) -> dict:
        """Execute one training step (caller holds the mesh context)."""
        t0 = time.time()
        batch = {"tokens": jnp.asarray(self.data.batch(step))}
        key = jax.random.fold_in(self._base_key, step)
        (self.params, self.opt_state, self.sync_state,
         metrics) = self._jitted(self.params, self.opt_state,
                                 self.sync_state, batch, key)
        loss = float(metrics["loss"])
        return {"step": step, "loss": round(loss, 5),
                "time_s": round(time.time() - t0, 3)}

    def run(self, n_steps: int | None = None) -> list:
        """Run to ``spec.steps`` (or ``n_steps`` more), firing callbacks.
        Returns the per-step records."""
        end = (self.spec.steps if n_steps is None
               else min(self.spec.steps, self.step + n_steps))
        history = []
        for cb in self.callbacks:
            cb.on_train_start(self)
        try:
            with jax.set_mesh(self.mesh):
                while self.step < end and not self.stop_requested:
                    record = self.run_step(self.step)
                    self.step = record["step"] + 1
                    self.last_record = record
                    for cb in self.callbacks:
                        cb.on_step_end(self, record)
                    history.append(record)
        finally:
            for cb in self.callbacks:
                cb.on_train_end(self)
        return history
