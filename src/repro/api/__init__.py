"""repro.api — the declarative entry-point layer.

  RunSpec / MeshSpec / CheckpointConfig  (spec.py)  : describe a scenario
  TrainSession                           (session.py): run it
  ServeSession                           (serve.py) : serve it
  ElasticTrainSession                    (repro.elastic): run it elastically
  build_* / *_sds helpers          (build.py, shapes.py): lower it

``launch/train.py``, ``launch/dryrun.py``, the examples, and the benchmark
harnesses are thin clients of this package; see README.md for the
quickstart and the scenario matrix.
"""
from ..collectives import SyncConfig
from ..data import DataConfig
from ..elastic import ElasticError, Membership
from ..elastic.config import ElasticConfig
from ..optim import AdamWConfig
from ..photonics import PhotonicsConfig
from ..serving.config import ServeConfig
from .build import (build_decode_step, build_prefill_step, build_train_step,
                    decode_cache_specs, init_sync_state,
                    modeled_bytes_on_wire, modeled_time_on_wire, param_specs,
                    sync_state_specs)
from .callbacks import (Callback, JsonlLogger, PeriodicCheckpoint,
                        SigtermHandler, StragglerWatchdog, default_callbacks)
from .serve import ServeSession
from .session import TrainSession
from .spec import (CheckpointConfig, MeshSpec, ResumeCompat, RunSpec,
                   SpecError, SpecMismatchError, check_resume_compat,
                   validate_resume_compat)

__all__ = [
    "RunSpec", "MeshSpec", "CheckpointConfig", "ServeConfig", "SyncConfig",
    "AdamWConfig", "DataConfig", "PhotonicsConfig", "ElasticConfig",
    "SpecError", "SpecMismatchError",
    "ResumeCompat", "check_resume_compat", "validate_resume_compat",
    "Membership", "ElasticError",
    "TrainSession", "ServeSession", "ElasticTrainSession",
    "Callback", "JsonlLogger", "PeriodicCheckpoint", "SigtermHandler",
    "StragglerWatchdog", "default_callbacks",
    "build_train_step", "build_prefill_step", "build_decode_step",
    "init_sync_state", "sync_state_specs", "decode_cache_specs",
    "param_specs", "modeled_bytes_on_wire", "modeled_time_on_wire",
]


def __getattr__(name):
    # ElasticTrainSession lives in repro.elastic (which imports repro.api
    # lazily); loading it on demand keeps the import graph cycle-free
    if name == "ElasticTrainSession":
        from ..elastic.session import ElasticTrainSession
        return ElasticTrainSession
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
