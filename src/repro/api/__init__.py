"""repro.api — the declarative entry-point layer.

  RunSpec / MeshSpec / CheckpointConfig  (spec.py)  : describe a scenario
  TrainSession                           (session.py): run it
  ServeSession                           (serve.py) : serve it
  build_* / *_sds helpers          (build.py, shapes.py): lower it

``launch/train.py``, ``launch/dryrun.py``, the examples, and the benchmark
harnesses are thin clients of this package; see README.md for the
quickstart and the scenario matrix.
"""
from ..collectives import SyncConfig
from ..data import DataConfig
from ..optim import AdamWConfig
from ..photonics import PhotonicsConfig
from ..serving.config import ServeConfig
from .build import (build_decode_step, build_prefill_step, build_train_step,
                    decode_cache_specs, init_sync_state, param_specs,
                    sync_state_specs)
from .callbacks import (Callback, JsonlLogger, PeriodicCheckpoint,
                        SigtermHandler, StragglerWatchdog, default_callbacks)
from .serve import ServeSession
from .session import TrainSession
from .spec import (CheckpointConfig, MeshSpec, RunSpec, SpecError,
                   SpecMismatchError, validate_resume_compat)

__all__ = [
    "RunSpec", "MeshSpec", "CheckpointConfig", "ServeConfig", "SyncConfig",
    "AdamWConfig", "DataConfig", "PhotonicsConfig", "SpecError",
    "SpecMismatchError",
    "validate_resume_compat",
    "TrainSession", "ServeSession",
    "Callback", "JsonlLogger", "PeriodicCheckpoint", "SigtermHandler",
    "StragglerWatchdog", "default_callbacks",
    "build_train_step", "build_prefill_step", "build_decode_step",
    "init_sync_state", "sync_state_specs", "decode_cache_specs",
    "param_specs",
]
