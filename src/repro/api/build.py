"""Spec -> step-builder bridge.

The only module that hands the MeshSpec parallelism fields
(fsdp / seq_parallel / remat_groups) to ``repro.launch.steps``, so the
train step, the sync-state initializer, and the serving steps can never
disagree on the state structure.  TrainSession, ServeSession, the dry-run,
and the benchmark harnesses all build their shard_map programs here.
"""
from __future__ import annotations

from ..launch import steps
from .spec import RunSpec


def _parts(spec: RunSpec, cfg, mesh):
    cfg = cfg if cfg is not None else spec.model_config()
    mesh = mesh if mesh is not None else spec.mesh.build()
    return cfg, mesh


def warmup_photonics(spec: RunSpec):
    """Resolve the in-network ONN(s) for spec's photonic fidelity eagerly
    (no-op for 'behavioral').  Sessions call this at build time so a slow
    params source ('train') or a missing one fails before the step loop,
    not in the middle of a shard_map trace."""
    sync = spec.resolved_sync()
    if sync.photonics.fidelity == "behavioral":
        return None
    from ..photonics import runtime
    m = spec.mesh
    module = runtime.warmup(sync, m.pods * m.dp)
    if sync.mode == "cascade":
        # the photonic cascade runs a level-0 ONN per pod (N1 = dp) in
        # addition to the full-N level-1 ONN resolved above
        runtime.warmup(sync, m.dp)
    if m.fsdp and m.pods > 1:
        # the FSDP-sharded leaf group syncs over the pod axis only
        runtime.warmup(sync, m.pods)
    return module


def modeled_time_on_wire(spec: RunSpec, cfg=None, overlap=None) -> float:
    """Analytic per-step wire-occupancy seconds for spec's sync scenario
    (backend ``time_on_wire``: line-rate transfer + per-bucket fabric
    reconfiguration, pipelined when overlap is on).  ``overlap`` overrides
    ``spec.sync.overlap``; pure arithmetic — no mesh or devices needed.
    The benchmarks report this next to the measured step time so the
    CPU-only perf gate can hold overlap-on to overlap-off without real
    transceivers (EXPERIMENTS.md §Overlap)."""
    from ..collectives import get_backend
    cfg = cfg if cfg is not None else spec.model_config()
    sync = spec.resolved_sync()
    ov = sync.overlap if overlap is None else overlap
    nbytes = 2 * cfg.param_count()          # bf16 gradient bytes
    n = spec.mesh.pods * spec.mesh.dp
    kw = {"n1": spec.mesh.dp} if sync.mode == "cascade" else {}
    return get_backend(sync.mode).time_on_wire(
        nbytes, n, sync.bits, overlap=ov,
        bucket_bytes=sync.bucket_bytes, **kw)


def modeled_bytes_on_wire(spec: RunSpec, cfg=None) -> float:
    """Analytic per-step optical-wire bytes for spec's sync scenario
    (backend ``bytes_on_wire`` over the live N = pods * dp, with the
    cascade's actual level-1 split N1 = dp).  Pure arithmetic — the
    elastic session logs this per membership epoch so a topology change
    is visible as a wire-cost change, and fig6 uses the same backend
    accounting."""
    from ..collectives import get_backend
    cfg = cfg if cfg is not None else spec.model_config()
    sync = spec.resolved_sync()
    nbytes = 2 * cfg.param_count()          # bf16 gradient bytes
    n = spec.mesh.pods * spec.mesh.dp
    kw = {"n1": spec.mesh.dp} if sync.mode == "cascade" else {}
    return get_backend(sync.mode).bytes_on_wire(nbytes, n, sync.bits, **kw)


def build_train_step(spec: RunSpec, cfg=None, mesh=None):
    """(step_fn, in_specs, out_specs) for spec's training scenario.
    step(params, opt_state, sync_state, batch, key) — shard_map'd, not
    jit'd (callers jit / lower)."""
    cfg, mesh = _parts(spec, cfg, mesh)
    m = spec.mesh
    return steps.make_train_step(
        cfg, mesh, spec.resolved_sync(), spec.optim, fsdp=m.fsdp,
        seq_parallel=m.seq_parallel, remat_groups=m.remat_groups)


def init_sync_state(spec: RunSpec, cfg=None, mesh=None):
    """Zero sync_state matching build_train_step's expectations ({} when
    error feedback is off)."""
    cfg, mesh = _parts(spec, cfg, mesh)
    m = spec.mesh
    return steps.init_sync_state(
        cfg, mesh, spec.resolved_sync(), fsdp=m.fsdp,
        seq_parallel=m.seq_parallel, remat_groups=m.remat_groups)


def sync_state_specs(spec: RunSpec, mesh=None):
    mesh = mesh if mesh is not None else spec.mesh.build()
    return steps.sync_state_specs(mesh, spec.resolved_sync())


def build_prefill_step(spec: RunSpec, cfg=None, mesh=None):
    cfg, mesh = _parts(spec, cfg, mesh)
    m = spec.mesh
    return steps.make_prefill_step(cfg, mesh, fsdp=m.fsdp,
                                   seq_parallel=m.seq_parallel,
                                   remat_groups=m.remat_groups)


def build_batched_prefill_step(spec: RunSpec, cfg=None, mesh=None):
    """Packed multi-prompt serving prefill (lm.batched_prefill_step):
    rows shard over the DP axes — the ServeEngine's prefill path."""
    cfg, mesh = _parts(spec, cfg, mesh)
    return steps.make_batched_prefill_step(cfg, mesh, fsdp=spec.mesh.fsdp)


def build_decode_step(spec: RunSpec, cfg=None, mesh=None, *,
                      seq_shard_cache: bool = False,
                      batch_shardable: bool = True):
    cfg, mesh = _parts(spec, cfg, mesh)
    return steps.make_decode_step(cfg, mesh, fsdp=spec.mesh.fsdp,
                                  seq_shard_cache=seq_shard_cache,
                                  batch_shardable=batch_shardable)


def decode_cache_specs(spec: RunSpec, cfg=None, *,
                       seq_shard_cache: bool = False,
                       batch_shardable: bool = True):
    cfg = cfg if cfg is not None else spec.model_config()
    ctx = spec.mesh.ctx(seq_shard_cache=seq_shard_cache)
    return steps.cache_specs(cfg, ctx, batch_shardable=batch_shardable)


def param_specs(spec: RunSpec, cfg=None):
    """(flat param PartitionSpecs, matching optimizer-state specs)."""
    from ..models import lm
    cfg = cfg if cfg is not None else spec.model_config()
    p = lm.flat_specs(cfg, spec.mesh.ctx())
    return p, steps.opt_specs(p)
