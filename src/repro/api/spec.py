"""Declarative run description: the repo's single programmatic surface.

A ``RunSpec`` is a frozen, JSON-serializable description of ONE scenario
(model x mesh x sync backend x optimizer x data x checkpointing).  Every
entry point — ``launch/train.py``, ``launch/dryrun.py``, the examples and
the benchmark harnesses — builds a RunSpec (from argparse flags or a JSON
file) and hands it to a Session; nothing outside ``repro.api`` derives
meshes, ``ShardCtx``, or step builders by hand.  Adding a scenario means
writing a spec, not a driver.

``MeshSpec`` replaces the loose ``(fsdp, seq_parallel, remat_groups, ...)``
kwarg quartet that previously had to be kept manually consistent across
``make_ctx`` / ``init_sync_state`` / ``make_train_step``: the ShardCtx is
derived here, in exactly one place (``MeshSpec.ctx``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

from ..collectives import SyncConfig, available_backends
from ..data import DataConfig
from ..elastic.config import ElasticConfig
from ..launch.mesh import make_mesh
from ..models.layers import ShardCtx
from ..optim import AdamWConfig
from ..photonics import FIDELITIES, MESH_BACKENDS
from ..serving.config import ServeConfig


class SpecError(ValueError):
    """A RunSpec is malformed or internally inconsistent."""


class SpecMismatchError(SpecError):
    """--resume found a checkpoint written by an incompatible RunSpec."""


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device-mesh topology + parallelism strategy.

    ``pods`` is the level-2 (cross-pod) data-parallel axis OptINC's cascade
    mode targets; ``dp`` x ``tp`` is the per-pod (data, model) grid.
    """
    dp: int = 1
    tp: int = 1
    pods: int = 1
    fsdp: bool = False
    seq_parallel: bool = False
    remat_groups: int = 0

    def __post_init__(self):
        if min(self.dp, self.tp, self.pods) < 1:
            raise SpecError(f"mesh sizes must be >= 1: {self}")
        if self.remat_groups < 0:
            raise SpecError(f"remat_groups must be >= 0: {self}")

    @property
    def shape(self) -> tuple:
        return ((self.pods, self.dp, self.tp) if self.pods > 1
                else (self.dp, self.tp))

    @property
    def axis_names(self) -> tuple:
        return (("pod", "data", "model") if self.pods > 1
                else ("data", "model"))

    @property
    def devices(self) -> int:
        return self.pods * self.dp * self.tp

    @classmethod
    def from_mesh(cls, mesh, *, fsdp: bool = False, seq_parallel: bool = False,
                  remat_groups: int = 0) -> "MeshSpec":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        return cls(dp=sizes.get("data", 1), tp=sizes.get("model", 1),
                   pods=sizes.get("pod", 1), fsdp=fsdp,
                   seq_parallel=seq_parallel, remat_groups=remat_groups)

    def build(self):
        """The jax Mesh for this topology (requires enough host devices)."""
        return make_mesh(self.shape, self.axis_names)

    def ctx(self, *, seq_shard_cache: bool = False) -> ShardCtx:
        """THE place a ShardCtx is derived from a mesh description."""
        return ShardCtx(tp=self.tp, dp=self.dp, pods=self.pods,
                        fsdp=self.fsdp, seq_shard_cache=seq_shard_cache,
                        seq_parallel=self.seq_parallel,
                        remat_groups=self.remat_groups)


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    dir: str = ""          # "" = checkpointing off
    every: int = 50        # save every N steps (and on stop / final step)
    keep: int = 3          # retained checkpoints
    resume: bool = False   # restart from the newest valid checkpoint


def _from_dict(cls, d):
    """Rebuild a (possibly nested) frozen config dataclass from JSON data,
    coercing lists back to tuples and rejecting unknown keys loudly."""
    if not isinstance(d, dict):
        raise SpecError(f"{cls.__name__} must be a JSON object, got {d!r}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - set(fields))
    if unknown:
        raise SpecError(f"unknown {cls.__name__} key(s): {unknown} "
                        f"(known: {sorted(fields)})")
    kw = {}
    for name, val in d.items():
        default = fields[name].default
        if dataclasses.is_dataclass(default) and isinstance(val, dict):
            val = _from_dict(type(default), val)
        elif isinstance(default, tuple) and isinstance(val, list):
            val = tuple(val)
        kw[name] = val
    try:
        return cls(**kw)
    except (TypeError, ValueError) as e:
        # config dataclasses validate in __post_init__ (e.g. an unknown
        # PhotonicsConfig fidelity) — surface those as spec errors too
        raise SpecError(f"invalid {cls.__name__}: {e}")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One fully-specified scenario. Frozen + JSON round-trippable."""
    arch: str = "paper_llama"
    smoke: bool = False                 # use the arch's reduced SMOKE config
    mesh: MeshSpec = MeshSpec()
    sync: SyncConfig = SyncConfig()
    optim: AdamWConfig = AdamWConfig()
    # vocab 0 = the model's vocab; seed matches RunSpec.seed's default so
    # the CLI keeps the legacy train.py behavior (--seed feeds both)
    data: DataConfig = DataConfig(vocab=0, seed=0)
    ckpt: CheckpointConfig = CheckpointConfig()
    serve: ServeConfig = ServeConfig()
    elastic: ElasticConfig = ElasticConfig()
    steps: int = 100
    seed: int = 0
    watchdog: float = 3.0               # straggler threshold (x median)
    log: str = ""                       # JSONL metrics file ("" = stdout only)

    # ------------------------------------------------ resolution helpers
    def model_config(self):
        from .. import configs
        try:
            return (configs.get_smoke(self.arch) if self.smoke
                    else configs.get(self.arch))
        except ModuleNotFoundError:
            raise SpecError(
                f"unknown arch {self.arch!r} (known: {configs.ARCHS})")

    def resolved_data(self) -> DataConfig:
        if self.data.vocab:
            return self.data
        return dataclasses.replace(self.data, vocab=self.model_config().vocab)

    def resolved_sync(self) -> SyncConfig:
        """Sync axes canonicalized to the mesh's DP axes."""
        axes = (("pod", "data") if self.mesh.pods > 1 else ("data",))
        return dataclasses.replace(self.sync, axes=axes)

    def validate(self) -> "RunSpec":
        self.model_config()
        if self.steps < 1:
            raise SpecError(f"steps must be >= 1, got {self.steps}")
        if self.sync.mode not in available_backends():
            raise SpecError(f"unknown sync backend {self.sync.mode!r} "
                            f"(registered: {sorted(available_backends())})")
        if (self.sync.mode == "cascade" and self.mesh.pods < 2
                and not (self.elastic.enabled or self.elastic.allow_reshard)):
            # an elastic run may legally shrink to one pod mid-flight (the
            # cascade degrades to its N2 == 1 one-level form), so the
            # two-pod floor only binds static topologies
            raise SpecError("--sync cascade needs a level-2 'pod' axis "
                            "(mesh.pods >= 2, e.g. --pods 2)")
        if self.elastic.enabled and self.sync.mode == "psum":
            raise SpecError(
                "--elastic re-derives the collective topology (cascade "
                "axes, carry grid, ONN programming) on membership change; "
                "--sync psum has no topology to re-derive — use "
                "optinc/cascade/ring")
        if self.elastic.enabled and not self.ckpt.dir:
            raise SpecError("--elastic resumes from the latest checkpoint "
                            "after a membership change and needs --ckpt-dir")
        # (an unknown fidelity/params value is rejected by PhotonicsConfig
        # itself at construction time — _from_dict wraps that in SpecError)
        ph = self.sync.photonics
        if (ph.fidelity != "behavioral"
                and self.sync.mode not in ("optinc", "cascade")):
            raise SpecError(
                f"--fidelity {ph.fidelity} is a photonic-backend knob "
                f"(the hardware-in-the-loop ONN path of optinc/cascade); "
                f"got --sync {self.sync.mode}")
        if ph.mesh_backend != "xla" and ph.fidelity != "mesh":
            raise SpecError(
                f"--mesh-backend {ph.mesh_backend} selects the MZI-emulator "
                f"executor and only applies to --fidelity mesh; got "
                f"--fidelity {ph.fidelity}")
        if (ph.fidelity != "behavioral" and self.sync.mode == "cascade"
                and self.sync.bits > 2):
            raise SpecError(
                f"the photonic cascade carries the eq.-10 decimal part on "
                f"the least-significant unit-P group, which is only on the "
                f"ONN's grid for bits <= 2; got --bits {self.sync.bits} "
                f"with --sync cascade --fidelity {ph.fidelity} (use "
                f"--fidelity behavioral for wider widths)")
        if ph.blk_b != 0 and ph.fidelity != "mesh":
            raise SpecError(
                f"--blk-b tiles the Pallas MZI-emulator kernel's batch and "
                f"only applies to --fidelity mesh; got --fidelity "
                f"{ph.fidelity}")
        if ((ph.theta_drift_std > 0 or ph.shot_noise_std > 0)
                and ph.fidelity != "mesh"):
            raise SpecError(
                f"--theta-drift-std/--shot-noise-std model the emulated MZI "
                f"mesh (PhaseNoise) and only apply to --fidelity mesh; got "
                f"--fidelity {ph.fidelity}")
        if self.sync.sparse_residuals and not self.sync.error_feedback:
            raise SpecError("--sparse-residuals compresses the checkpointed "
                            "error-feedback residuals and needs "
                            "--error-feedback")
        if self.sync.bucket_bytes <= 0:
            raise SpecError(f"bucket_bytes must be > 0, "
                            f"got {self.sync.bucket_bytes}")
        dp_total = self.mesh.pods * self.mesh.dp
        if self.data.global_batch % dp_total:
            raise SpecError(f"global_batch {self.data.global_batch} not "
                            f"divisible by pods*dp = {dp_total}")
        if self.ckpt.resume and not self.ckpt.dir:
            raise SpecError("ckpt.resume requires ckpt.dir")
        if self.serve.max_seq < self.serve.page_size:
            raise SpecError(f"serve.max_seq ({self.serve.max_seq}) must be "
                            f">= serve.page_size ({self.serve.page_size})")
        if self.serve.top_k and self.serve.temperature == 0:
            raise SpecError("--top-k samples from the softmax and needs "
                            "--temperature > 0 (temperature 0 = greedy)")
        if self.serve.reload_every and not self.ckpt.dir:
            raise SpecError("--reload-every polls the checkpoint directory "
                            "and needs --ckpt-dir")
        return self

    # ------------------------------------------------ JSON round-trip
    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_json_dict(), indent=indent)

    @classmethod
    def from_json_dict(cls, d: dict) -> "RunSpec":
        return _from_dict(cls, d)

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        return cls.from_json_dict(json.loads(text))

    def save(self, path) -> None:
        pathlib.Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "RunSpec":
        try:
            text = pathlib.Path(path).read_text()
        except OSError as e:
            raise SpecError(f"cannot read spec file {path}: {e}")
        try:
            return cls.from_json(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"spec file {path} is not valid JSON: {e}")

    # ------------------------------------------------ resume compatibility
    def state_fingerprint(self) -> dict:
        """The spec fields that determine checkpoint state CONTENT — the
        global shapes and meaning of the saved arrays.  These must match
        EXACTLY across any resume, resharded or not."""
        return {"arch": self.arch, "smoke": self.smoke,
                "moment_dtype": self.optim.moment_dtype,
                "error_feedback": self.sync.error_feedback}

    def shape_fingerprint(self) -> dict:
        """The spec fields that determine only the state's PLACEMENT (mesh
        axes / sharding).  These may differ across a resume when
        resharding is allowed: the global arrays re-place onto the new
        mesh's NamedShardings."""
        return {"mesh": dataclasses.asdict(self.mesh)}

    def compat_fingerprint(self) -> dict:
        """state_fingerprint | shape_fingerprint — the legacy exact-match
        fingerprint (kept: external spec files may reference it)."""
        return {**self.state_fingerprint(), **self.shape_fingerprint()}

    # ------------------------------------------------ CLI surface
    @staticmethod
    def add_args(ap: argparse.ArgumentParser) -> None:
        """The shared train-style CLI; every flag overrides the
        corresponding RunSpec field (absent flags leave the base spec —
        defaults or --spec file — untouched)."""
        ap.add_argument("--spec", help="RunSpec JSON file (flags override)")
        ap.add_argument("--arch", help="architecture id (repro.configs)")
        ap.add_argument("--smoke-config", action="store_true",
                        help="use the arch's reduced SMOKE config")
        ap.add_argument("--sync", choices=sorted(available_backends()),
                        help="gradient-sync backend")
        ap.add_argument("--bucket-mb", type=float,
                        help="fused gradient-bucket size in MiB")
        ap.add_argument("--pods", type=int,
                        help="pod (level-2) axis size; 0 = auto (2 for "
                             "--sync cascade, else 1)")
        ap.add_argument("--bits", type=int, help="OptINC bit width B")
        ap.add_argument("--overlap", action="store_true",
                        help="stream buckets in gradient-readiness order so "
                             "collectives overlap the remaining backward "
                             "(bit-exact vs the barrier path)")
        ap.add_argument("--fidelity", choices=FIDELITIES,
                        help="optinc/cascade emulation depth: behavioral "
                             "Q(mean) | trained dense ONN | MZI mesh "
                             "emulator (repro.photonics)")
        ap.add_argument("--mesh-backend", choices=MESH_BACKENDS,
                        help="fidelity=mesh executor: per-layer XLA scan | "
                             "fused Pallas VMEM kernel (kernels.mesh_scan)")
        ap.add_argument("--blk-b", type=int,
                        help="Pallas mesh-kernel batch tile (rows per VMEM "
                             "tile, multiple of 8; 0 = default 128 — sweep "
                             "with benchmarks/mesh_emulation.py "
                             "--blk-b-sweep)")
        ap.add_argument("--theta-drift-std", type=float,
                        help="PhaseNoise: thermal drift std (rad) on every "
                             "programmed MZI phase (fidelity=mesh)")
        ap.add_argument("--shot-noise-std", type=float,
                        help="PhaseNoise: additive noise std on the mesh's "
                             "analog outputs (fidelity=mesh)")
        ap.add_argument("--error-layers",
                        help="Table II key, e.g. '3,4,5,6' (ONN errors)")
        ap.add_argument("--error-feedback", action="store_true")
        ap.add_argument("--sparse-residuals", action="store_true",
                        help="checkpoint error-feedback residuals "
                             "block-sparsely (only blocks with nonzero "
                             "carry)")
        ap.add_argument("--fsdp", action="store_true",
                        help="shard params over the data axis (ZeRO-3)")
        ap.add_argument("--seq-parallel", action="store_true")
        ap.add_argument("--remat-groups", type=int)
        ap.add_argument("--steps", type=int)
        ap.add_argument("--global-batch", type=int)
        ap.add_argument("--seq-len", type=int)
        ap.add_argument("--lr", type=float)
        ap.add_argument("--mesh", help="DPxTP, e.g. 4x1")
        ap.add_argument("--ckpt-dir")
        ap.add_argument("--ckpt-every", type=int)
        ap.add_argument("--ckpt-keep", type=int)
        ap.add_argument("--resume", action="store_true")
        # elastic membership runtime (RunSpec.elastic — repro.elastic)
        ap.add_argument("--elastic", action="store_true",
                        help="elastic: watch the membership registry and "
                             "re-derive the collective topology + "
                             "reshard-resume when a pod drops or joins")
        ap.add_argument("--heartbeat-s", type=float,
                        help="elastic: heartbeat period / liveness poll "
                             "granularity in seconds")
        ap.add_argument("--allow-reshard", action="store_true",
                        help="permit --resume onto a different mesh shape "
                             "(compatible-reshard restore: global state "
                             "re-placed, error-feedback residuals "
                             "re-bucketized)")
        ap.add_argument("--members-dir",
                        help="elastic: membership registry directory "
                             "(default <ckpt-dir>/members)")
        ap.add_argument("--evict-after", type=int,
                        help="elastic: consecutive straggler flags before "
                             "the watchdog reports a member suspect "
                             "(0 = observe only)")
        ap.add_argument("--watchdog", type=float)
        ap.add_argument("--seed", type=int)
        ap.add_argument("--log", help="JSONL metrics file")
        # serving tier (RunSpec.serve — repro.serving)
        ap.add_argument("--page-size", type=int,
                        help="serving: tokens per paged-KV page")
        ap.add_argument("--max-active", type=int,
                        help="serving: concurrently decoding sequences")
        ap.add_argument("--max-queue", type=int,
                        help="serving: queued-request cap")
        ap.add_argument("--max-seq", type=int,
                        help="serving: per-sequence cache capacity "
                             "(prompt + generation)")
        ap.add_argument("--max-new-tokens", type=int,
                        help="serving: default per-request generation budget")
        ap.add_argument("--stop-token", type=int,
                        help="serving: end-of-sequence token id (-1 = none)")
        ap.add_argument("--temperature", type=float,
                        help="serving: sampling temperature (0 = greedy)")
        ap.add_argument("--top-k", type=int,
                        help="serving: sample from the k best logits "
                             "(0 = full vocab)")
        ap.add_argument("--serve-pages", type=int,
                        help="serving: physical KV pool size in pages "
                             "(0 = auto, pressure-free)")
        ap.add_argument("--reload-every", type=int,
                        help="serving: poll --ckpt-dir for newer params "
                             "every N engine steps (hot-swap; 0 = off)")
        ap.add_argument("--decode-backend", choices=("gather", "paged"),
                        help="serving: decode attention path — 'gather' "
                             "copies pages contiguous, 'paged' attends "
                             "over the pool in place (Pallas kernel on "
                             "TPU, gather fallback elsewhere)")
        ap.add_argument("--kv-dtype", choices=("auto", "f32", "bf16"),
                        help="serving: KV pool storage dtype ('bf16' "
                             "halves pool bytes; attention accumulates "
                             "f32 either way)")

    @classmethod
    def from_args(cls, argv=None, description: str | None = None) -> "RunSpec":
        ap = argparse.ArgumentParser(
            description=description, argument_default=argparse.SUPPRESS,
            formatter_class=argparse.RawDescriptionHelpFormatter)
        cls.add_args(ap)
        ns = vars(ap.parse_args(argv))
        base = cls.load(ns.pop("spec")) if "spec" in ns else cls()
        return base.apply_cli(ns).validate()

    def apply_cli(self, ns: dict) -> "RunSpec":
        """Overlay a dict of (present-only) CLI args onto this spec."""
        ns = dict(ns)
        mesh_kw, sync_kw, opt_kw = {}, {}, {}
        data_kw, ckpt_kw, top_kw = {}, {}, {}
        if "arch" in ns:
            top_kw["arch"] = ns.pop("arch")
        if "smoke_config" in ns:
            top_kw["smoke"] = ns.pop("smoke_config")
        if "mesh" in ns:
            raw = ns.pop("mesh")
            try:
                mesh_kw["dp"], mesh_kw["tp"] = (int(x) for x in raw.split("x"))
            except ValueError:
                raise SpecError(f"--mesh must be DPxTP (e.g. 4x1): {raw!r}")
        pods = ns.pop("pods", None)
        for k in ("fsdp", "seq_parallel", "remat_groups"):
            if k in ns:
                mesh_kw[k] = ns.pop(k)
        if "sync" in ns:
            sync_kw["mode"] = ns.pop("sync")
        if "bits" in ns:
            sync_kw["bits"] = ns.pop("bits")
        if "overlap" in ns:
            sync_kw["overlap"] = ns.pop("overlap")
        ph_kw = {}
        if "fidelity" in ns:
            ph_kw["fidelity"] = ns.pop("fidelity")
        if "mesh_backend" in ns:
            ph_kw["mesh_backend"] = ns.pop("mesh_backend")
        if "blk_b" in ns:
            ph_kw["blk_b"] = ns.pop("blk_b")
        if "theta_drift_std" in ns:
            ph_kw["theta_drift_std"] = ns.pop("theta_drift_std")
        if "shot_noise_std" in ns:
            ph_kw["shot_noise_std"] = ns.pop("shot_noise_std")
        if ph_kw:
            sync_kw["photonics"] = dataclasses.replace(
                self.sync.photonics, **ph_kw)
        if "bucket_mb" in ns:
            sync_kw["bucket_bytes"] = int(ns.pop("bucket_mb") * 2 ** 20)
        if "error_layers" in ns:
            raw = ns.pop("error_layers")
            sync_kw["error_layers"] = (tuple(int(x) for x in raw.split(","))
                                       if raw else ())
        if "error_feedback" in ns:
            sync_kw["error_feedback"] = ns.pop("error_feedback")
        if "sparse_residuals" in ns:
            sync_kw["sparse_residuals"] = ns.pop("sparse_residuals")
        if "lr" in ns:
            opt_kw["lr"] = ns.pop("lr")
        if "seq_len" in ns:
            data_kw["seq_len"] = ns.pop("seq_len")
        if "global_batch" in ns:
            data_kw["global_batch"] = ns.pop("global_batch")
        if "seed" in ns:
            top_kw["seed"] = data_kw["seed"] = ns.pop("seed")
        if "ckpt_dir" in ns:
            ckpt_kw["dir"] = ns.pop("ckpt_dir")
        if "ckpt_every" in ns:
            ckpt_kw["every"] = ns.pop("ckpt_every")
        if "ckpt_keep" in ns:
            ckpt_kw["keep"] = ns.pop("ckpt_keep")
        if "resume" in ns:
            ckpt_kw["resume"] = ns.pop("resume")
        serve_kw = {}
        for k in ("page_size", "max_active", "max_queue", "max_seq",
                  "max_new_tokens", "stop_token", "temperature", "top_k",
                  "reload_every", "decode_backend", "kv_dtype"):
            if k in ns:
                serve_kw[k] = ns.pop(k)
        if "serve_pages" in ns:
            serve_kw["pages"] = ns.pop("serve_pages")
        elastic_kw = {}
        if "elastic" in ns:
            elastic_kw["enabled"] = ns.pop("elastic")
        if "heartbeat_s" in ns:
            elastic_kw["heartbeat_s"] = ns.pop("heartbeat_s")
        if "allow_reshard" in ns:
            elastic_kw["allow_reshard"] = ns.pop("allow_reshard")
        if "members_dir" in ns:
            elastic_kw["dir"] = ns.pop("members_dir")
        if "evict_after" in ns:
            elastic_kw["evict_after"] = ns.pop("evict_after")
        for k in ("steps", "watchdog", "log"):
            if k in ns:
                top_kw[k] = ns.pop(k)
        if ns:
            raise SpecError(f"unhandled CLI key(s): {sorted(ns)}")
        mode = sync_kw.get("mode", self.sync.mode)
        if pods is not None and pods > 0:
            mesh_kw["pods"] = pods
        else:  # absent or 0: auto — cascade needs its level-2 axis
            cur = mesh_kw.get("pods", self.mesh.pods)
            if mode == "cascade" and cur < 2:
                mesh_kw["pods"] = 2
        return dataclasses.replace(
            self,
            mesh=dataclasses.replace(self.mesh, **mesh_kw),
            sync=dataclasses.replace(self.sync, **sync_kw),
            optim=dataclasses.replace(self.optim, **opt_kw),
            data=dataclasses.replace(self.data, **data_kw),
            ckpt=dataclasses.replace(self.ckpt, **ckpt_kw),
            serve=dataclasses.replace(self.serve, **serve_kw),
            elastic=dataclasses.replace(self.elastic, **elastic_kw),
            **top_kw)


@dataclasses.dataclass(frozen=True)
class ResumeCompat:
    """Structured verdict of a checkpoint-vs-run spec comparison.

    ``verdict``:
      * ``"exact"``        — fingerprints identical; bit-exact restore.
      * ``"reshardable"``  — state fields match, only mesh/placement
        fields differ; restorable via the compatible-reshard path
        (params/optimizer re-placed, residuals re-bucketized).
      * ``"incompatible"`` — state fields differ; the saved arrays do
        not describe this run's state.
    """
    verdict: str                      # exact | reshardable | incompatible
    state_diff: tuple = ()            # differing state_fingerprint keys
    shape_diff: tuple = ()            # differing shape_fingerprint keys
    detail: str = ""                  # human-readable field-by-field diff

    @property
    def ok(self) -> bool:
        return self.verdict != "incompatible"


def _diff(a: dict, b: dict) -> tuple:
    return tuple(k for k in b if a.get(k) != b[k])


def check_resume_compat(saved: RunSpec, current: RunSpec) -> ResumeCompat:
    """Pure comparison — never raises.  ``validate_resume_compat`` turns
    this verdict into the enforcement policy."""
    state = _diff(saved.state_fingerprint(), current.state_fingerprint())
    shape = _diff(saved.shape_fingerprint(), current.shape_fingerprint())
    sa, sb = saved.compat_fingerprint(), current.compat_fingerprint()
    detail = "; ".join(f"{k}: checkpoint={sa.get(k)!r} vs run={sb[k]!r}"
                       for k in state + shape)
    verdict = ("incompatible" if state
               else "reshardable" if shape else "exact")
    return ResumeCompat(verdict=verdict, state_diff=state, shape_diff=shape,
                        detail=detail)


def validate_resume_compat(saved: RunSpec, current: RunSpec,
                           allow_reshard: bool = False) -> ResumeCompat:
    """Enforce resume compatibility and return the verdict.

    ``incompatible`` always raises SpecMismatchError (unchanged contract:
    the saved arrays cannot express this run's state).  ``reshardable``
    raises too unless ``allow_reshard`` — resuming onto a different mesh
    shape is deliberate, not a typo, so it is gated behind
    ``--allow-reshard`` (or an ``--elastic`` run, which implies it).
    """
    compat = check_resume_compat(saved, current)
    if compat.verdict == "incompatible":
        raise SpecMismatchError(
            f"checkpoint was written by an incompatible RunSpec "
            f"({compat.detail}). Start a fresh run (drop --resume / change "
            f"--ckpt-dir) or match the checkpointed spec.")
    if compat.verdict == "reshardable" and not allow_reshard:
        raise SpecMismatchError(
            f"checkpoint was written on a different mesh shape "
            f"({compat.detail}). Pass --allow-reshard to resume via the "
            f"compatible-reshard path (global state re-placed onto the new "
            f"mesh; error-feedback residuals re-bucketized), or match the "
            f"checkpointed mesh.")
    return compat
