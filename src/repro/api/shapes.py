"""Abstract (ShapeDtypeStruct) argument builders for lowering without
materializing arrays — shared by the multi-pod dry-run and the wire-byte
benchmarks so every harness lowers exactly the programs the sessions run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.config import ModelConfig


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_sds(cfg: ModelConfig, seq_len: int, global_batch: int):
    b = {"tokens": sds((global_batch, seq_len), jnp.int32)}
    if cfg.enc_dec:
        b["enc_frames"] = sds((global_batch, cfg.enc_frames, cfg.d_model),
                              jnp.bfloat16)
    return b


def opt_sds(params_sds, moment_dtype=jnp.float32):
    m = jax.tree.map(lambda s: sds(s.shape, moment_dtype), params_sds)
    return {"m": m, "v": jax.tree.map(lambda s: sds(s.shape, moment_dtype), m),
            "step": sds((), jnp.int32)}


def cache_sds(cfg: ModelConfig, ctx, batch_local: int, max_seq: int):
    """LOCAL (per-shard) decode-cache shapes via eval_shape."""
    tree = jax.eval_shape(lambda: lm.init_cache(cfg, ctx, batch_local, max_seq))
    return jax.tree.map(lambda s: sds(s.shape, s.dtype), tree)


def globalize_cache_sds(local_sds, cache_spec, mesh):
    """Scale local shard shapes back up to global shapes by the specs."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s, spec):
        shp = list(s.shape)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shp[i] *= sizes[a]
        return sds(shp, s.dtype)

    return jax.tree.map(one, local_sds, cache_spec,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
