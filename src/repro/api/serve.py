"""ServeSession: prefill + decode + KV-cache management behind one object.

``examples/serve_decode.py`` and the dry-run decode cells previously each
re-derived mesh/ShardCtx and wired the serving steps by hand; both now go
through ``repro.api.build`` — ServeSession is the *runtime* face of that
shared path (real arrays, greedy generation), the dry-run is the
*lowering* face (abstract shapes).

Parameters come from (in order of precedence): the ``params`` argument,
the spec's checkpoint directory when ``ckpt.resume`` is set (serve a
trained run), or a fresh seeded init — the same ``serving.reload``
resolution the continuous-batching ServeEngine uses.

``generate`` runs the compiled prefill step over the whole prompt (one
forward, causal-masked) and seeds the decode cache from its KV, instead
of replaying the prompt token-by-token through the decode step — the
prompt costs one program launch instead of ``prompt_len``.  Both paths
are greedy and bit-exact with each other (tests/test_serving.py); the
replay path survives for the flash-decode seq-sharded cache layout,
whose sequence axis the prefill output is not sharded over.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import compat  # noqa: F401  (jax API shims)
from ..models import lm
from ..serving import reload as serving_reload
from . import build
from .spec import RunSpec


class ServeSession:
    def __init__(self, spec: RunSpec, params=None, *,
                 seq_shard_cache: bool = False, batch_shardable: bool = True):
        spec.validate()
        self.spec = spec
        self.cfg = spec.model_config()
        self.mesh = spec.mesh.build()
        self.ctx = spec.mesh.ctx(seq_shard_cache=seq_shard_cache)
        if params is not None:
            self.params, self.params_step = params, None
        else:
            self.params, self.params_step = serving_reload.resolve_params(
                spec, self.cfg, self.mesh)
        pre, _, _ = build.build_prefill_step(spec, self.cfg, self.mesh)
        dec, _, _ = build.build_decode_step(
            spec, self.cfg, self.mesh, seq_shard_cache=seq_shard_cache,
            batch_shardable=batch_shardable)
        self._prefill = jax.jit(pre)
        self._decode = jax.jit(dec, donate_argnums=(1,))
        self._seed = jax.jit(self._seed_cache, donate_argnums=(0,))

    @staticmethod
    def _seed_cache(full, pre):
        """Copy a prefill cache into a fresh full-length decode cache:
        leaves whose shapes already match (recurrent states, cross-attn
        KV) are taken as-is; KV leaves are placed at sequence offset 0."""
        def leaf(f, p):
            if f.shape == p.shape:
                return p.astype(f.dtype)
            return jax.lax.dynamic_update_slice(f, p.astype(f.dtype),
                                                (0,) * f.ndim)
        return jax.tree.map(leaf, full, pre)

    # ------------------------------------------------------------ serving
    def prefill(self, tokens, enc_frames=None):
        """(logits_at_last_position, prefill_cache) for a prompt batch."""
        feed = {"tokens": jnp.asarray(tokens)}
        if self.cfg.enc_dec:
            feed["enc_frames"] = enc_frames
        with jax.set_mesh(self.mesh):
            return self._prefill(self.params, feed)

    def new_cache(self, batch: int, max_seq: int):
        with jax.set_mesh(self.mesh):
            return lm.init_cache(self.cfg, self.ctx, batch, max_seq)

    def decode(self, cache, token, pos: int):
        """One decode step; the cache argument is donated."""
        with jax.set_mesh(self.mesh):
            return self._decode(self.params, cache, token, jnp.int32(pos))

    def engine(self):
        """A continuous-batching ServeEngine over this session's spec and
        params (paged KV pool, per-request scheduling — repro.serving)."""
        from ..serving.engine import ServeEngine
        return ServeEngine(self.spec, params=self.params)

    def generate(self, prompts, gen_len: int, max_seq: int | None = None,
                 enc_frames=None):
        """Greedy decode: compiled prefill over the prompt, decode cache
        seeded from the prefill KV, then argmax sampling one token per
        decode step.  Returns (batch, gen_len) int token ids."""
        if self.ctx.seq_shard_cache:
            # the flash-decode cache shards its sequence axis over 'data';
            # prefill output is not in that layout, so replay the prompt
            return self._generate_replay(prompts, gen_len, max_seq)
        prompts = jnp.asarray(prompts)
        batch, prompt_len = prompts.shape
        max_seq = max_seq or prompt_len + gen_len
        assert max_seq >= prompt_len + gen_len, (max_seq, prompt_len, gen_len)
        logits, pre = self.prefill(prompts, enc_frames=enc_frames)
        cache = self.new_cache(batch, max_seq)
        with jax.set_mesh(self.mesh):
            cache = self._seed(cache, pre)
            out = []
            tok = jnp.argmax(logits[:, :self.cfg.vocab], -1)[:, None]
            out.append(tok)
            for i in range(gen_len - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(prompt_len + i))
                tok = jnp.argmax(logits[:, :self.cfg.vocab], -1)[:, None]
                out.append(tok)
        return jnp.concatenate(out, axis=1)

    def _generate_replay(self, prompts, gen_len: int,
                         max_seq: int | None = None):
        """Token-by-token reference path: replay the prompt through the
        decode step (same cache layout the dry-run cells lower), then
        sample argmax tokens."""
        prompts = jnp.asarray(prompts)
        batch, prompt_len = prompts.shape
        max_seq = max_seq or prompt_len + gen_len
        assert max_seq >= prompt_len + gen_len, (max_seq, prompt_len, gen_len)
        cache = self.new_cache(batch, max_seq)
        with jax.set_mesh(self.mesh):
            logits = None
            for i in range(prompt_len):
                logits, cache = self._decode(self.params, cache,
                                             prompts[:, i:i + 1], jnp.int32(i))
            out = []
            tok = jnp.argmax(logits[:, :self.cfg.vocab], -1)[:, None]
            out.append(tok)
            for i in range(gen_len - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(prompt_len + i))
                tok = jnp.argmax(logits[:, :self.cfg.vocab], -1)[:, None]
                out.append(tok)
        return jnp.concatenate(out, axis=1)
