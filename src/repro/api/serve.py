"""ServeSession: prefill + decode + KV-cache management behind one object.

``examples/serve_decode.py`` and the dry-run decode cells previously each
re-derived mesh/ShardCtx and wired the serving steps by hand; both now go
through ``repro.api.build`` — ServeSession is the *runtime* face of that
shared path (real arrays, greedy generation), the dry-run is the
*lowering* face (abstract shapes).

Parameters come from (in order of precedence): the ``params`` argument,
the spec's checkpoint directory when ``ckpt.resume`` is set (serve a
trained run), or a fresh seeded init.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import compat  # noqa: F401  (jax API shims)
from ..checkpoint import load_checkpoint
from ..checkpoint.ckpt import latest_step
from ..models import lm
from . import build
from .spec import RunSpec


class ServeSession:
    def __init__(self, spec: RunSpec, params=None, *,
                 seq_shard_cache: bool = False, batch_shardable: bool = True):
        spec.validate()
        self.spec = spec
        self.cfg = spec.model_config()
        self.mesh = spec.mesh.build()
        self.ctx = spec.mesh.ctx(seq_shard_cache=seq_shard_cache)
        self.params = (params if params is not None
                       else self._init_or_load_params())
        pre, _, _ = build.build_prefill_step(spec, self.cfg, self.mesh)
        dec, _, _ = build.build_decode_step(
            spec, self.cfg, self.mesh, seq_shard_cache=seq_shard_cache,
            batch_shardable=batch_shardable)
        self._prefill = jax.jit(pre)
        self._decode = jax.jit(dec, donate_argnums=(1,))

    def _init_or_load_params(self):
        c = self.spec.ckpt
        step = latest_step(c.dir) if (c.dir and c.resume) else None
        if step is None:
            return lm.init_params(self.cfg, self.ctx,
                                  jax.random.PRNGKey(self.spec.seed))
        # load_checkpoint only reads the template's structure and dtypes —
        # an eval_shape template skips materializing a throwaway init
        template = jax.eval_shape(
            lambda: lm.init_params(self.cfg, self.ctx, jax.random.PRNGKey(0)))
        p_specs, _ = build.param_specs(self.spec, self.cfg)
        tree, _ = load_checkpoint(c.dir, step, {"params": template},
                                  mesh=self.mesh, specs={"params": p_specs})
        print(f"serving params from checkpoint step {step}", flush=True)
        return tree["params"]

    # ------------------------------------------------------------ serving
    def prefill(self, tokens, enc_frames=None):
        """(logits_at_last_position, prefill_cache) for a prompt batch."""
        feed = {"tokens": jnp.asarray(tokens)}
        if self.cfg.enc_dec:
            feed["enc_frames"] = enc_frames
        with jax.set_mesh(self.mesh):
            return self._prefill(self.params, feed)

    def new_cache(self, batch: int, max_seq: int):
        with jax.set_mesh(self.mesh):
            return lm.init_cache(self.cfg, self.ctx, batch, max_seq)

    def decode(self, cache, token, pos: int):
        """One decode step; the cache argument is donated."""
        with jax.set_mesh(self.mesh):
            return self._decode(self.params, cache, token, jnp.int32(pos))

    def generate(self, prompts, gen_len: int, max_seq: int | None = None):
        """Greedy decode: replay the prompt through the decode path (same
        cache layout the dry-run cells lower), then sample argmax tokens.
        Returns (batch, gen_len) int token ids."""
        prompts = jnp.asarray(prompts)
        batch, prompt_len = prompts.shape
        max_seq = max_seq or prompt_len + gen_len
        assert max_seq >= prompt_len + gen_len, (max_seq, prompt_len, gen_len)
        cache = self.new_cache(batch, max_seq)
        with jax.set_mesh(self.mesh):
            logits = None
            for i in range(prompt_len):
                logits, cache = self._decode(self.params, cache,
                                             prompts[:, i:i + 1], jnp.int32(i))
            out = []
            tok = jnp.argmax(logits[:, :self.cfg.vocab], -1)[:, None]
            out.append(tok)
            for i in range(gen_len - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(prompt_len + i))
                tok = jnp.argmax(logits[:, :self.cfg.vocab], -1)[:, None]
                out.append(tok)
        return jnp.concatenate(out, axis=1)
