"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------- pam4 -------------------------------

def pam4_quantize_encode_ref(g: jnp.ndarray, scale: jnp.ndarray, bits: int,
                             block: int) -> jnp.ndarray:
    """Block-quantize fp32 gradients to offset-binary B-bit ints (what the
    transceivers put on the fiber). g: (nblocks, block), scale: (nblocks,).
    Returns int32 (nblocks, block) in [0, 2^B - 2]."""
    levels = 2 ** (bits - 1) - 1
    q = jnp.round(g.astype(jnp.float32) / scale[:, None] * levels)
    q = jnp.clip(q, -levels, levels).astype(jnp.int32)
    return q + levels


def pam4_decode_dequantize_ref(u_avg: jnp.ndarray, scale: jnp.ndarray,
                               bits: int) -> jnp.ndarray:
    """Averaged offset-binary ints -> fp32 gradients. u_avg: (nblocks, block)."""
    levels = 2 ** (bits - 1) - 1
    return (u_avg.astype(jnp.float32) - levels) * (scale[:, None] / levels)


def pam4_qmean_ref(total: jnp.ndarray, n: int) -> jnp.ndarray:
    """The ONN behavioural transfer function on the integer sum (eq. 3)."""
    return jnp.round(total.astype(jnp.float32) / n).astype(jnp.int32)


# ----------------------------- onn layer ----------------------------

def onn_layer_ref(x: jnp.ndarray, u: jnp.ndarray, d: jnp.ndarray,
                  b: jnp.ndarray, relu: bool = True) -> jnp.ndarray:
    """Fused approximated ONN layer: y = act(d * (x @ u^T) + b).

    x: (batch, n), u: (m, n) orthogonal, d: (m,), b: (m,)."""
    y = x @ u.T * d + b
    return jax.nn.relu(y) if relu else y


# ---------------------------- attention -----------------------------

def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """Reference attention. q: (sq, d), k/v: (skv, d). Single head."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if causal:
        sq, skv = s.shape
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
