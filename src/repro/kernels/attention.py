"""Pallas TPU flash-attention kernel (online softmax over KV tiles).

The generic perf-critical layer of the model zoo: prefill attention at 32k
sequence cannot materialize (sq, skv) scores in HBM. We tile Q into
(BLK_Q, d) blocks resident in VMEM, stream K/V tiles, and keep the running
max / normalizer / output accumulator in VMEM scratch — O(sq * d) memory.

Single-head kernel; ops.py vmaps over (batch, heads) and handles GQA
broadcasting. Causal masking is computed from program ids, and fully-masked
KV tiles are skipped via the grid (no wasted MXU work past the diagonal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, blk_q: int, blk_k: int,
                  kv_steps: int, sq: int, skv: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: rows attend to kv positions <= row + (skv - sq)
    @pl.when((ki * blk_k <= qi * blk_q + blk_q - 1 + (skv - sq))
             if causal else (ki >= 0))
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows + (skv - sq), s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Single-head attention. q: (sq, d), k/v: (skv, d)."""
    sq, d = q.shape
    skv = k.shape[0]
    if scale is None:
        scale = d ** -0.5
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    assert sq % blk_q == 0 and skv % blk_k == 0
    kv_steps = skv // blk_k
    grid = (sq // blk_q, kv_steps)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          blk_q=blk_q, blk_k=blk_k, kv_steps=kv_steps,
                          sq=sq, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((blk_k, d), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((blk_k, d), lambda qi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((blk_q, d), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
