"""Pallas TPU kernel for the approximated ONN layer y = act(d*(x U^T) + b).

The Sigma_a U_a structure (paper eq. 4) makes the diagonal scale a free
epilogue on the MXU matmul: we tile (batch x n) @ (n x m) with MXU-aligned
128x128 blocks, accumulate over the K dimension in VMEM scratch, and fuse
the diagonal scale, bias and ReLU into the final K-step epilogue — one HBM
write for the whole layer instead of matmul + 3 elementwise passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..photonics.config import resolve_interpret


def _onn_layer_kernel(x_ref, ut_ref, d_ref, b_ref, y_ref, acc_ref, *,
                      relu: bool, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], ut_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        y = acc_ref[...] * d_ref[...] + b_ref[...]
        if relu:
            y = jnp.maximum(y, 0.0)
        y_ref[...] = y.astype(y_ref.dtype)


def onn_layer(x: jnp.ndarray, u: jnp.ndarray, d: jnp.ndarray, b: jnp.ndarray,
              relu: bool = True, blk_b: int = 128, blk_m: int = 128,
              blk_k: int = 128, interpret: bool | None = None) -> jnp.ndarray:
    """x: (batch, n), u: (m, n) orthogonal block row, d/b: (m,).

    Tiles must divide the (padded) operands; the ops.py wrapper pads.
    ``interpret=None`` auto-detects (compiled only on TPU)."""
    interpret = resolve_interpret(interpret)
    batch, n = x.shape
    m = u.shape[0]
    blk_b = min(blk_b, batch)
    blk_m = min(blk_m, m)
    blk_k = min(blk_k, n)
    assert batch % blk_b == 0 and m % blk_m == 0 and n % blk_k == 0
    k_steps = n // blk_k
    grid = (batch // blk_b, m // blk_m, k_steps)
    ut = u.T  # (n, m) for row-major MXU feeding
    return pl.pallas_call(
        functools.partial(_onn_layer_kernel, relu=relu, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_b, blk_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((blk_k, blk_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, blk_m), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, blk_m), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((blk_b, blk_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m), x.dtype),
        scratch_shapes=[pltpu.VMEM((blk_b, blk_m), jnp.float32)],
        interpret=interpret,
    )(x, ut, d.reshape(1, -1), b.reshape(1, -1))
