"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled
(interpret=False); on CPU (this container) the *model code* uses the pure
jnp references so dry-runs lower to ordinary HLO, while tests run the
Pallas kernel bodies in interpret mode against the references.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from . import pam4 as pam4_k
from . import onn_layer as onn_k
from . import attention as attn_k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------- pam4 -------------------------------

@partial(jax.jit, static_argnames=("bits",))
def pam4_quantize_encode(g, scale, bits: int = 8):
    if _on_tpu():
        # interpret=None auto-resolves to compiled on TPU
        return pam4_k.pam4_quantize_encode(g, scale, bits)
    return ref.pam4_quantize_encode_ref(g, scale, bits, g.shape[-1])


@partial(jax.jit, static_argnames=("bits", "n"))
def pam4_decode_dequantize(total, scale, bits: int, n: int):
    if _on_tpu():
        return pam4_k.pam4_decode_dequantize(total, scale, bits, n)
    u_avg = ref.pam4_qmean_ref(total, n)
    return ref.pam4_decode_dequantize_ref(u_avg, scale, bits)


# ----------------------------- onn layer ----------------------------

@partial(jax.jit, static_argnames=("relu",))
def onn_layer(x, u, d, b, relu: bool = True):
    if _on_tpu():
        return onn_k.onn_layer(x, u, d, b, relu=relu)
    return ref.onn_layer_ref(x, u, d, b, relu=relu)


# ---------------------------- attention -----------------------------

@partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal: bool = True):
    """Multi-head GQA attention. q: (b, hq, sq, d), k/v: (b, hkv, skv, d)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    if _on_tpu():
        f = partial(attn_k.flash_attention, causal=causal, interpret=False)
    else:
        f = partial(ref.mha_ref, causal=causal)
    return jax.vmap(jax.vmap(f))(q, k, v)
