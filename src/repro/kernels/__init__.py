# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
#   pam4.py       fused quantize + PAM4-encode (paper eq. 2)
#   onn_layer.py  MXU matmul + diag/bias/ReLU epilogue (paper eq. 4)
#   mesh_scan.py  fused L-layer MZI rotation cascade in VMEM
#                 (PhotonicsConfig.mesh_backend = 'pallas')
