"""Pallas TPU kernels for the PAM4 gradient-encoding hot path.

Every training step quantizes/encodes the full gradient (hundreds of MB to
GB) and decodes the averaged result — a pure memory-bound streaming op that
the paper offloads to the transceivers. On TPU we fuse
scale-multiply / round / clip / offset into one VMEM pass per tile so the
gradient is read from HBM exactly once.

Tiling: gradients are viewed as (nblocks, block) with ``block`` a multiple
of 128 (lane dim); each grid step processes a (BLK_R, block) tile with the
per-block scales resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..photonics.config import resolve_interpret


def _encode_kernel(g_ref, scale_ref, u_ref, *, levels: int):
    g = g_ref[...]
    s = scale_ref[...]             # (BLK_R, 1)
    q = jnp.round(g / s * levels)
    q = jnp.clip(q, -levels, levels)
    u_ref[...] = (q + levels).astype(jnp.int32)


def _decode_kernel(u_ref, scale_ref, g_ref, *, levels: int, n: int):
    total = u_ref[...].astype(jnp.float32)
    # Q(mean): the ONN behavioural transfer function on the integer sum
    u_avg = jnp.round(total / n)
    s = scale_ref[...]
    g_ref[...] = (u_avg - levels) * (s / levels)


def pam4_quantize_encode(g: jnp.ndarray, scale: jnp.ndarray, bits: int,
                         blk_r: int = 8, interpret: bool | None = None):
    """g: (nblocks, block) fp32, scale: (nblocks,) -> int32 offset-binary.

    ``interpret=None`` auto-detects (compiled on TPU, interpreted
    elsewhere — photonics.resolve_interpret)."""
    interpret = resolve_interpret(interpret)
    levels = 2 ** (bits - 1) - 1
    nblocks, block = g.shape
    assert nblocks % blk_r == 0, (nblocks, blk_r)
    grid = (nblocks // blk_r,)
    return pl.pallas_call(
        functools.partial(_encode_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_r, block), lambda i: (i, 0)),
            pl.BlockSpec((blk_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_r, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.int32),
        interpret=interpret,
    )(g.astype(jnp.float32), scale.reshape(-1, 1))


def pam4_decode_dequantize(total: jnp.ndarray, scale: jnp.ndarray, bits: int,
                           n: int, blk_r: int = 8,
                           interpret: bool | None = None):
    """Fused Q(mean) + dequantize of the integer all-reduce result.

    total: (nblocks, block) int32 sum over N peers; returns fp32 gradients.
    ``interpret=None`` auto-detects (compiled only on TPU)."""
    interpret = resolve_interpret(interpret)
    levels = 2 ** (bits - 1) - 1
    nblocks, block = total.shape
    assert nblocks % blk_r == 0
    grid = (nblocks // blk_r,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, levels=levels, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_r, block), lambda i: (i, 0)),
            pl.BlockSpec((blk_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_r, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.float32),
        interpret=interpret,
    )(total, scale.reshape(-1, 1))
