"""Pallas TPU kernel: the fused L-layer MZI-mesh cascade in VMEM.

``photonics.mesh.MZIMesh.apply`` lowers to one XLA gather + FMA per
rotation layer under ``lax.scan`` — L round-trips of the batch tile
through HBM for an L-layer Clements cascade.  This kernel keeps the
whole compiled program resident instead: the three (L, m) layer stacks
(partner permutation ``perm``, diagonal ``ca``, off-diagonal ``sa``)
plus one batch tile live in VMEM together, and a ``fori_loop`` applies
all L layers back to back — ONE HBM read and ONE HBM write per batch
tile for the entire mesh, however deep it is.

``mesh_scan_blocks`` is the block-batched form: the stacked block axis
of ``ApproxLayerProgram`` (B same-width meshes applied to the same — or
a per-block — batch) is folded into the ``pallas_call`` grid as
``grid = (B, batch_tiles)`` instead of an outer ``jax.vmap`` of B
separate kernel launches.  The batch-tile axis iterates fastest, so
each block's (L, m) stacks are fetched into VMEM once and reused across
every batch tile (pallas double-buffers the per-block fetch while the
previous block computes); a shared batch tile is re-read per block from
its HBM-resident pad, never re-materialized per block in XLA.

The per-layer wire shuffle ``y[..., perm]`` is not a native TPU lane
operation; it is realized as a one-hot matmul on the MXU:

    P[i, j] = (perm[j] == i)          (built in-VMEM from an iota)
    y[..., perm] = y @ P

so a layer is one (blk_b, m) x (m, m) MXU pass + a fused VPU FMA.  When
the full (L, m, m) one-hot stack fits a VMEM scratch budget
(``ONEHOT_CACHE_BYTES``), it is built ONCE per block — at the first
batch tile, persisting in scratch across grid steps — instead of
rebuilt from the iota compare inside every tile's layer loop.  The sign
column and an optional diagonal epilogue (the Sigma_a ``d`` scale of
``ApproxLayerProgram``) ride along as free pre/post VPU multiplies, so
the whole ``diag(post) . G_1^T..G_K^T . diag(pre)`` chain is one kernel.

PhaseNoise theta drift is drawn IN-KERNEL: with ``theta_std > 0`` each
block's grid step derives a (L, m) standard-normal field from a per-block
uint32 seed (folded off the step key by the caller) via a counter-based
splitmix32 hash + Box-Muller — no perturbed (ca, sa) stacks are ever
materialized in XLA, and the same portable uint32 arithmetic runs
compiled and interpreted.  ``theta_std == 0`` traces NONE of the noise
code (no seed operand, no extra ops), so the zero-noise kernel stays
bit-exact with the noise-free parity rows.  Shot noise (additive, on
the output) stays an XLA epilogue in ``photonics.mesh``.

VMEM budget (f32, the compiled-TPU case): the layer stacks cost
3 * L * m_pad * 4 bytes and the tile 2 * blk_b * m_pad * 4; the one-hot
scratch cache adds L * m_pad^2 * 4 when enabled (capped at
``ONEHOT_CACHE_BYTES`` = 4 MiB, falling back to the in-loop iota build
for deeper/wider programs); for the deepest program in the repo
(m = 256, L ~ 2m = 512) that is ~1.6 MiB + ~0.5 MiB — comfortably
inside the ~16 MiB/core budget with the default blk_b = 128.

``interpret`` auto-detects via ``photonics.resolve_interpret`` (compiled
on TPU, interpreted everywhere else); the interpreted path runs the
identical one-hot math, so CPU CI exercises the same numerics the TPU
executes.  ``photonics.mesh`` keeps the pure-XLA scan as the fallback
backend (``mesh_backend='xla'``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..photonics.config import resolve_interpret

DEFAULT_BLK_B = 128        # batch rows per tile (PhotonicsConfig.blk_b = 0)
ONEHOT_CACHE_BYTES = 4 * 2 ** 20  # VMEM budget for the per-block one-hot stack


def _round_up(n: int, k: int) -> int:
    return -(-n // k) * k


# ------------------------------ in-kernel PRNG ------------------------------

def _mix32(x):
    """splitmix32-style avalanche of a uint32 counter word."""
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    return x ^ (x >> jnp.uint32(16))


def _normal_field(seed, n_layers: int, m: int, dt):
    """(L, m) standard normals from one uint32 seed, counter-based.

    Two independent uint32 hash streams per (layer, wire) counter feed a
    Box-Muller transform.  Plain jnp uint32 arithmetic — identical bits
    compiled and interpreted, unlike ``pltpu.prng_random_bits`` (which
    has no CPU interpreter lowering on this jax), so CPU CI can
    statistically validate the same draws the TPU makes.
    """
    row = jax.lax.broadcasted_iota(jnp.uint32, (n_layers, m), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (n_layers, m), 1)
    base = (row * jnp.uint32(m) + col) * jnp.uint32(0x9E3779B9) + seed
    h1 = _mix32(base)
    h2 = _mix32(base ^ jnp.uint32(0x85EBCA6B))
    # 24-bit mantissa uniforms; u1 in (0, 1] keeps the log finite
    u1 = ((h1 >> jnp.uint32(8)).astype(dt) + 1.0) * jnp.asarray(2.0 ** -24, dt)
    u2 = (h2 >> jnp.uint32(8)).astype(dt) * jnp.asarray(2.0 ** -24, dt)
    r = jnp.sqrt(jnp.asarray(-2.0, dt) * jnp.log(u1))
    return r * jnp.cos(jnp.asarray(2.0 * jnp.pi, dt) * u2)


# --------------------------------- kernel -----------------------------------

def _mesh_scan_blocks_kernel(*refs, n_layers: int, transpose: bool,
                             x_blocked: bool, theta_std: float,
                             cache_onehot: bool):
    """One (block, batch-tile) grid step of the fused cascade.

    refs: perm, ca, sa, pre, post, x[, seed] | out | [onehot scratch].
    """
    if theta_std > 0.0:
        (perm_ref, ca_ref, sa_ref, pre_ref, post_ref, x_ref, seed_ref,
         y_ref, *scratch) = refs
    else:
        (perm_ref, ca_ref, sa_ref, pre_ref, post_ref, x_ref,
         y_ref, *scratch) = refs
        seed_ref = None
    oh_ref = scratch[0] if cache_onehot else None

    dt = y_ref.dtype
    m = pre_ref.shape[-1]
    y = (x_ref[0] if x_blocked else x_ref[...]) * pre_ref[...]
    # wire[i, j] = i; comparing against a perm row makes the one-hot
    # permutation matrix P with P[i, j] = (perm[j] == i), so y @ P is
    # y[..., perm] (TPU needs >= 2-D iota)
    wire = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)

    if cache_onehot:
        # built once per block: the batch-tile axis is the fastest grid
        # dim and scratch persists across grid steps, so tiles j > 0
        # reuse the stack tile j == 0 materialized
        @pl.when(pl.program_id(1) == 0)
        def _build():
            def build(l, carry):
                p = perm_ref[0, pl.ds(l, 1), :]               # (1, m)
                oh_ref[pl.ds(l, 1)] = ((wire == p).astype(dt))[None]
                return carry
            jax.lax.fori_loop(0, n_layers, build, 0)

    g = None
    if theta_std > 0.0:
        # one drift field per BLOCK and apply — identical across the
        # block's batch tiles (one physical mesh per block), varying only
        # with the per-block seed the caller folded off the step key
        g = _normal_field(seed_ref[0, 0].astype(jnp.uint32),
                          n_layers, m, dt)

    def body(i, y):
        l = (n_layers - 1 - i) if transpose else i
        p = perm_ref[0, pl.ds(l, 1), :]                       # (1, m)
        ca = ca_ref[0, pl.ds(l, 1), :]
        sa = sa_ref[0, pl.ds(l, 1), :]
        if cache_onehot:
            onehot = oh_ref[pl.ds(l, 1)][0]                   # (m, m)
        else:
            # HIGHEST precision: the MXU's default truncates f32 inputs
            # to bf16, which would round y on every one of the L layers —
            # selection through an exact 0/1 matrix must stay exact
            onehot = (wire == p).astype(dt)
        if theta_std > 0.0:
            # pipeline.PhaseNoise.perturb, per layer: one gaussian per
            # wire, symmetrized over the partner permutation (the
            # one-hot matmul IS g[perm]), antisymmetric sign ->
            # coherent theta -> theta + eps on both wires of each MZI;
            # untouched wires (perm == self) get sign 0, eps 0 exactly
            g_row = jax.lax.dynamic_slice(g, (l, 0), (1, m))
            g_p = jnp.dot(g_row, onehot, preferred_element_type=dt,
                          precision=jax.lax.Precision.HIGHEST)
            lane = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
            sgn = jnp.sign(lane - p).astype(dt)
            delta = jnp.asarray(0.5 ** 0.5, dt) * (g_row + g_p)
            eps = jnp.asarray(theta_std, dt) * delta * sgn
            ce, se = jnp.cos(eps), jnp.sin(eps)
            ca, sa = ca * ce - sa * se, sa * ce + ca * se
        y_p = jnp.dot(y, onehot, preferred_element_type=dt,
                      precision=jax.lax.Precision.HIGHEST)
        # forward applies G^T (the compiled sa), transpose applies G
        return ca * y - sa * y_p if transpose else ca * y + sa * y_p

    y = jax.lax.fori_loop(0, n_layers, body, y)
    y_ref[...] = (y * post_ref[...]).astype(dt)[None]


# ------------------------------- dispatchers --------------------------------

def mesh_scan_blocks(signs: jnp.ndarray, perm: jnp.ndarray, ca: jnp.ndarray,
                     sa: jnp.ndarray, x: jnp.ndarray, *,
                     x_block_axis: bool = False, transpose: bool = False,
                     post_scale: jnp.ndarray | None = None,
                     interpret: bool | None = None, blk_b: int = 0,
                     theta_std: float = 0.0,
                     seeds: jnp.ndarray | None = None) -> jnp.ndarray:
    """Apply B stacked rotation-layer programs in ONE kernel launch.

    ``signs`` is (B, m); ``perm``/``ca``/``sa`` are the (B, L, m) stacks
    of ``photonics.mesh._stack_meshes``.  ``x`` is shared across blocks
    (``(..., m)``) or carries its own block axis at -2
    (``x_block_axis``, ``(..., B, m)``); the result is ``(..., B, m)`` —
    the contract of ``photonics.mesh._apply_stacked``, without its outer
    ``jax.vmap`` of B separate ``pallas_call``s: the block axis is a
    grid dimension, batch tiles iterate fastest, and each block's stacks
    are fetched into VMEM once.

    ``post_scale`` (B, m) is each block's fused diagonal epilogue.
    ``theta_std`` > 0 enables the in-kernel PhaseNoise theta drift,
    seeded per block from ``seeds`` (B,) uint32; 0 compiles the exact
    noise-free kernel (statically — no seed operand exists).
    ``blk_b`` tiles the batch (0 = ``DEFAULT_BLK_B``).
    """
    interpret = resolve_interpret(interpret)
    n_blocks, n_layers, m = perm.shape
    dt = jnp.result_type(x.dtype, ca.dtype)
    if theta_std > 0.0 and seeds is None:
        raise ValueError("mesh_scan_blocks: theta_std > 0 needs per-block "
                         "uint32 seeds")

    batch_shape = x.shape[:-2] if x_block_axis else x.shape[:-1]
    if x_block_axis:
        if x.shape[-2] != n_blocks:
            raise ValueError(f"x block axis {x.shape[-2]} != {n_blocks}")
        # (..., B, m) -> (B, batch, m): each block's batch pad is a
        # contiguous HBM operand the grid tiles at (i, j)
        y = jnp.moveaxis(x.astype(dt).reshape(-1, n_blocks, m), 1, 0)
    else:
        y = x.astype(dt).reshape(-1, m)
    batch = y.shape[-2]
    if batch == 0:
        return jnp.zeros(batch_shape + (n_blocks, m), dt)

    ones = jnp.ones((n_blocks, m), dt)
    pre = ones if transpose else signs.astype(dt)
    post = signs.astype(dt) if transpose else ones
    if post_scale is not None:
        post = post * post_scale.astype(dt)

    # pad wires to the 128-lane tile (identity rotations: perm = self,
    # ca = 1, sa = 0, so padded lanes stay at their zero-padded inputs)
    # and the batch to the chosen sublane tile
    m_pad = _round_up(max(m, 1), 128)
    blk_b = int(blk_b) or DEFAULT_BLK_B
    blk_b = min(blk_b, _round_up(batch, 8))
    b_pad = _round_up(batch, blk_b)
    if m_pad != m:
        pad_ids = jnp.broadcast_to(jnp.arange(m, m_pad, dtype=perm.dtype),
                                   (n_blocks, n_layers, m_pad - m))
        perm = jnp.concatenate([perm, pad_ids], axis=-1)
        ca = jnp.pad(ca, ((0, 0), (0, 0), (0, m_pad - m)), constant_values=1)
        sa = jnp.pad(sa, ((0, 0), (0, 0), (0, m_pad - m)))
        pre = jnp.pad(pre, ((0, 0), (0, m_pad - m)), constant_values=1)
        post = jnp.pad(post, ((0, 0), (0, m_pad - m)), constant_values=1)
    bp = b_pad - batch
    if x_block_axis:
        y = jnp.pad(y, ((0, 0), (0, bp), (0, m_pad - m)))
    else:
        y = jnp.pad(y, ((0, bp), (0, m_pad - m)))

    n_tiles = b_pad // blk_b
    # the one-hot scratch cache only pays when >1 tile reuses it and the
    # whole (L, m_pad, m_pad) stack fits the VMEM budget
    oh_bytes = n_layers * m_pad * m_pad * jnp.dtype(dt).itemsize
    cache_onehot = n_tiles > 1 and oh_bytes <= ONEHOT_CACHE_BYTES

    stack_spec = pl.BlockSpec((1, n_layers, m_pad), lambda i, j: (i, 0, 0))
    col_spec = pl.BlockSpec((1, m_pad), lambda i, j: (i, 0))
    in_specs = [stack_spec, stack_spec, stack_spec, col_spec, col_spec]
    operands = [perm, ca.astype(dt), sa.astype(dt), pre, post]
    if x_block_axis:
        in_specs.append(pl.BlockSpec((1, blk_b, m_pad),
                                     lambda i, j: (i, j, 0)))
    else:
        in_specs.append(pl.BlockSpec((blk_b, m_pad), lambda i, j: (j, 0)))
    operands.append(y)
    if theta_std > 0.0:
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, 0)))
        operands.append(seeds.astype(jnp.uint32).astype(jnp.int32)
                        .reshape(n_blocks, 1))

    out = pl.pallas_call(
        functools.partial(_mesh_scan_blocks_kernel, n_layers=n_layers,
                          transpose=transpose, x_blocked=x_block_axis,
                          theta_std=float(theta_std),
                          cache_onehot=cache_onehot),
        grid=(n_blocks, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, blk_b, m_pad), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, b_pad, m_pad), dt),
        scratch_shapes=([pltpu.VMEM((n_layers, m_pad, m_pad), dt)]
                        if cache_onehot else []),
        interpret=interpret,
    )(*operands)
    # (B, batch, m) -> (..., B, m)
    out = jnp.moveaxis(out[:, :batch, :m], 0, 1)
    return out.reshape(batch_shape + (n_blocks, m))


def mesh_scan(signs: jnp.ndarray, perm: jnp.ndarray, ca: jnp.ndarray,
              sa: jnp.ndarray, x: jnp.ndarray, transpose: bool = False,
              post_scale: jnp.ndarray | None = None,
              interpret: bool | None = None, blk_b: int = 0,
              theta_std: float = 0.0,
              seed: jnp.ndarray | None = None) -> jnp.ndarray:
    """Apply a compiled rotation-layer stack to ``x`` in one fused kernel.

    Semantically identical to ``MZIMesh.apply`` (o @ x over the last axis,
    o^T @ x when ``transpose``), with an optional fused diagonal epilogue
    ``post_scale`` multiplied into the output.  ``perm``/``ca``/``sa`` are
    the (L, m) stacks of ``MZIMesh``; ``signs`` is its (m,) sign column.
    Arbitrary leading batch dims on ``x`` are flattened into the grid.
    The single-mesh entry point is the B = 1 case of
    ``mesh_scan_blocks``; ``theta_std``/``seed`` enable the in-kernel
    PhaseNoise theta drift.
    """
    out = mesh_scan_blocks(
        signs[None], perm[None], ca[None], sa[None], x,
        x_block_axis=False, transpose=transpose,
        post_scale=None if post_scale is None else post_scale[None],
        interpret=interpret, blk_b=blk_b, theta_std=theta_std,
        seeds=None if seed is None else jnp.reshape(seed, (1,)))
    return out[..., 0, :]
