"""Pallas TPU kernel: the fused L-layer MZI-mesh cascade in VMEM.

``photonics.mesh.MZIMesh.apply`` lowers to one XLA gather + FMA per
rotation layer under ``lax.scan`` — L round-trips of the batch tile
through HBM for an L-layer Clements cascade.  This kernel keeps the
whole compiled program resident instead: the three (L, m) layer stacks
(partner permutation ``perm``, diagonal ``ca``, off-diagonal ``sa``)
plus one batch tile live in VMEM together, and a ``fori_loop`` applies
all L layers back to back — ONE HBM read and ONE HBM write per batch
tile for the entire mesh, however deep it is.

The per-layer wire shuffle ``y[..., perm]`` is not a native TPU lane
operation; it is realized as a one-hot matmul on the MXU:

    P[i, j] = (perm[j] == i)          (built in-VMEM from an iota)
    y[..., perm] = y @ P

so a layer is one (blk_b, m) x (m, m) MXU pass + a fused VPU FMA.  The
sign column and an optional diagonal epilogue (the Sigma_a ``d`` scale
of ``ApproxLayerProgram`` — the same fusion ``kernels/onn_layer.py``
gives the dense path) ride along as free pre/post VPU multiplies, so
the whole ``diag(post) . G_1^T..G_K^T . diag(pre)`` chain is one kernel.

VMEM budget (f32, the compiled-TPU case): the layer stacks cost
3 * L * m_pad * 4 bytes and the tile 2 * blk_b * m_pad * 4 + m_pad^2 * 4
(one-hot scratch); for the deepest program in the repo (m = 256,
L ~ 2m = 512) that is ~1.6 MiB + ~0.5 MiB — comfortably inside the
~16 MiB/core budget with the default blk_b = 128.

``interpret`` auto-detects via ``photonics.resolve_interpret`` (compiled
on TPU, interpreted everywhere else); the interpreted path runs the
identical one-hot math, so CPU CI exercises the same numerics the TPU
executes.  ``photonics.mesh`` keeps the pure-XLA scan as the fallback
backend (``mesh_backend='xla'``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..photonics.config import resolve_interpret


def _round_up(n: int, k: int) -> int:
    return -(-n // k) * k


def _mesh_scan_kernel(perm_ref, ca_ref, sa_ref, pre_ref, post_ref, x_ref,
                      y_ref, *, n_layers: int, transpose: bool):
    dt = y_ref.dtype
    y = x_ref[...] * pre_ref[...]
    m = y.shape[-1]
    # wire[i, j] = i; comparing against a perm row makes the one-hot
    # permutation matrix P with P[i, j] = (perm[j] == i), so y @ P is
    # y[..., perm] (TPU needs >= 2-D iota)
    wire = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)

    def body(i, y):
        l = (n_layers - 1 - i) if transpose else i
        p = perm_ref[pl.ds(l, 1), :]                    # (1, m)
        ca = ca_ref[pl.ds(l, 1), :]
        sa = sa_ref[pl.ds(l, 1), :]
        # HIGHEST precision: the MXU's default truncates f32 inputs to
        # bf16, which would round y on every one of the L layers —
        # selection through an exact 0/1 matrix must stay exact
        onehot = (wire == p).astype(dt)
        y_p = jnp.dot(y, onehot, preferred_element_type=dt,
                      precision=jax.lax.Precision.HIGHEST)
        # forward applies G^T (the compiled sa), transpose applies G
        return ca * y - sa * y_p if transpose else ca * y + sa * y_p

    y = jax.lax.fori_loop(0, n_layers, body, y)
    y_ref[...] = (y * post_ref[...]).astype(dt)


def mesh_scan(signs: jnp.ndarray, perm: jnp.ndarray, ca: jnp.ndarray,
              sa: jnp.ndarray, x: jnp.ndarray, transpose: bool = False,
              post_scale: jnp.ndarray | None = None,
              interpret: bool | None = None, blk_b: int = 128) -> jnp.ndarray:
    """Apply a compiled rotation-layer stack to ``x`` in one fused kernel.

    Semantically identical to ``MZIMesh.apply`` (o @ x over the last axis,
    o^T @ x when ``transpose``), with an optional fused diagonal epilogue
    ``post_scale`` multiplied into the output.  ``perm``/``ca``/``sa`` are
    the (L, m) stacks of ``MZIMesh``; ``signs`` is its (m,) sign column.
    Arbitrary leading batch dims on ``x`` are flattened into the grid.
    """
    interpret = resolve_interpret(interpret)
    n_layers, m = perm.shape
    dt = jnp.result_type(x.dtype, ca.dtype)
    batch_shape = x.shape[:-1]
    y = x.astype(dt).reshape(-1, m)
    if y.shape[0] == 0:
        return y.reshape(batch_shape + (m,))
    batch = y.shape[0]

    ones = jnp.ones((m,), dt)
    pre = ones if transpose else signs.astype(dt)
    post = signs.astype(dt) if transpose else ones
    if post_scale is not None:
        post = post * post_scale.astype(dt)

    # pad wires to the 128-lane tile (identity rotations: perm = self,
    # ca = 1, sa = 0, so padded lanes stay at their zero-padded inputs)
    # and the batch to the chosen sublane tile
    m_pad = _round_up(max(m, 1), 128)
    blk_b = min(blk_b, _round_up(batch, 8))
    b_pad = _round_up(batch, blk_b)
    if m_pad != m:
        pad_ids = jnp.broadcast_to(jnp.arange(m, m_pad, dtype=perm.dtype),
                                   (n_layers, m_pad - m))
        perm = jnp.concatenate([perm, pad_ids], axis=-1)
        ca = jnp.pad(ca, ((0, 0), (0, m_pad - m)), constant_values=1)
        sa = jnp.pad(sa, ((0, 0), (0, m_pad - m)))
        pre = jnp.pad(pre, (0, m_pad - m), constant_values=1)
        post = jnp.pad(post, (0, m_pad - m), constant_values=1)
    if b_pad != y.shape[0]:
        y = jnp.pad(y, ((0, b_pad - y.shape[0]), (0, 0)))
    if m_pad != m:
        y = jnp.pad(y, ((0, 0), (0, m_pad - m)))

    out = pl.pallas_call(
        functools.partial(_mesh_scan_kernel, n_layers=n_layers,
                          transpose=transpose),
        grid=(b_pad // blk_b,),
        in_specs=[
            pl.BlockSpec((n_layers, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((n_layers, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((n_layers, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((blk_b, m_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_b, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, m_pad), dt),
        interpret=interpret,
    )(perm, ca.astype(dt), sa.astype(dt), pre.reshape(1, -1),
      post.reshape(1, -1), y)
    return out[:batch, :m].reshape(batch_shape + (m,))
