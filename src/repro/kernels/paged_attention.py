"""Pallas TPU kernel: paged-attention decode straight over the KV pool.

The serving tier's gather path (``models.layers.paged_gather`` feeding
``decode_attention``) materializes every active sequence's pages as a
contiguous ``(b, hkv, nb * page, hd)`` buffer before the softmax — an
O(active * max_seq) HBM copy per decode step per layer, twice (K and V).
This kernel attends over the physical pool IN PLACE instead: the grid
runs ``(slots, kv_heads, page_tiles)`` with the page-tile axis fastest,
each slot's page-table row is scalar-prefetched (SMEM) so the K and V
``BlockSpec`` index maps can steer the next page's DMA straight out of
the pool into VMEM, and a running online-softmax state ``(m, l, acc)``
in VMEM scratch folds one ``(page, hd)`` tile into the slot's attention
output per grid step — no contiguous KV copy ever exists.

Semantics match ``decode_attention`` over the gathered view exactly:
positions ``>= lengths[slot]`` are masked to ``NEG_INF`` score (zero
weight), which covers both the zero tail of a sequence's last page and
every page-table entry still pointing at the reserved null page 0 —
whatever those pages hold is masked out by the position test, never by
trusting pool contents.  Query scaling, f32 accumulation (KV pages may
be stored bf16 — ``ServeConfig.kv_dtype``), the GQA query-group
broadcast and the ``max(l, 1e-30)`` guard are the same ops in the same
precision; the only difference from the gather path is the online
tile-by-tile association of the softmax sums, so kernel and oracle agree
to float-associativity (~1e-6), not bitwise.

VMEM budget per grid step (f32): a ``(rep, hd)`` query block, two
``(page, hd)`` KV pages and the ``(rep, hd + 2)`` scratch state — for
the largest serving shapes in the repo (rep 8, hd 128, page 64) well
under 100 KiB against the ~16 MiB/core budget; pallas double-buffers the
next page's fetch behind the current tile's FLOPs.

``use_kernel`` decides dispatch: the kernel runs compiled on TPU;
everywhere else ``decode_backend='paged'`` falls back to the XLA gather
path (``models.blocks.gqa_decode_paged``), which stays bit-exact with
``decode_backend='gather'`` by construction.  Tests force the
interpreted kernel (``interpret=True`` here, ``FORCE_KERNEL`` for the
engine path) to run the same numerics on CPU CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..photonics.config import resolve_interpret

NEG_INF = -1e30  # models.layers.NEG_INF: finite, exp(NEG_INF - m) == 0.0

# test hook: True forces the (interpreted, off-TPU) kernel into the
# serving dispatch, False forces the gather fallback, None = platform
FORCE_KERNEL: bool | None = None


def use_kernel(flag: bool | None = None) -> bool:
    """Should ``decode_backend='paged'`` run the Pallas kernel?  Compiled
    on TPU; elsewhere the XLA gather path is the fallback (interpret-mode
    pallas is a test vehicle, not a serving path).  Explicit flag (or the
    module-level ``FORCE_KERNEL`` test hook) wins."""
    if flag is not None:
        return bool(flag)
    if FORCE_KERNEL is not None:
        return bool(FORCE_KERNEL)
    return jax.default_backend() == "tpu"


def _paged_attention_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                            m_ref, l_ref, acc_ref, *, page_size: int,
                            n_blocks: int):
    """One (slot, kv_head, page_tile) grid step: fold one physical page
    into the slot's online-softmax state; write the output at the last
    tile.  pt_ref/len_ref are the scalar-prefetched page tables (flat)
    and per-slot valid counts — already consumed by the K/V index maps,
    len_ref again here for the validity mask."""
    i, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                    # (rep, hd) f32 scaled
    k = k_ref[0, 0].astype(jnp.float32)                # (page, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (rep, page)
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    s = jnp.where(pos < len_ref[i], s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    m_ref[...] = m_cur
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    page_table: jnp.ndarray, lengths: jnp.ndarray, *,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Decode attention for a packed slot batch, read straight off the
    physical page pool.

    q: (b, h, 1, hd) one pending query per slot; k_pool/v_pool:
    (P, hkv_local, page, hd) shared physical pages (any float dtype —
    accumulation is f32); page_table: (b, nb) per-slot page ids in
    logical-block order (null page 0 beyond a slot's allocation);
    lengths: (b,) valid cache positions per slot — the ``lengths + 1``
    the gather path passes to ``decode_attention`` (the pending token's
    KV must already be written to its page).  Returns (b, h, 1, hd) in
    q.dtype, equal to ``decode_attention(ctx, q, paged_gather(k_pool,
    page_table), paged_gather(v_pool, page_table), lengths)`` up to
    online-softmax float associativity.
    """
    interpret = resolve_interpret(interpret)
    b, h, one, hd = q.shape
    assert one == 1, q.shape
    n_pages, hkv, ps, _ = k_pool.shape
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    nb = page_table.shape[1]
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, hkv, rep, hd)
    pt = page_table.reshape(b * nb).astype(jnp.int32)

    def q_map(i, g, j, pt_ref, len_ref):
        return (i, g, 0, 0)

    def kv_map(i, g, j, pt_ref, len_ref):
        # the scalar-prefetched page table steers the DMA: page tile j of
        # slot i is fetched from wherever that slot's j-th page lives
        return (pt_ref[i * nb + j], g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), q_map),
            pl.BlockSpec((1, 1, ps, hd), kv_map),
            pl.BlockSpec((1, 1, ps, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), q_map),
        scratch_shapes=[pltpu.VMEM((rep, 1), jnp.float32),
                        pltpu.VMEM((rep, 1), jnp.float32),
                        pltpu.VMEM((rep, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_attention_kernel, page_size=ps,
                          n_blocks=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, hd), jnp.float32),
        interpret=interpret,
    )(pt, lengths.astype(jnp.int32), qf, k_pool, v_pool)
    return out.reshape(b, h, 1, hd).astype(q.dtype)
