"""One-shot fixup: early sweep records divided per-device stats by chips;
multiply back and recompute roofline terms (idempotent via raw_stats flag)."""
import json, pathlib, sys
sys.path.insert(0, "src")
from repro.launch import roofline

for p in pathlib.Path("results/dryrun").glob("*.json"):
    r = json.loads(p.read_text())
    if r.get("skipped") or r.get("raw_stats"):
        continue
    c = r["chips"]
    r["flops_per_device"] = r["flops_per_device"] * c
    r["bytes_per_device"] = r["bytes_per_device"] * c
    for k in ("argument_bytes", "output_bytes", "temp_bytes", "alias_bytes",
              "peak_bytes"):
        if r["memory"].get(k) is not None:
            r["memory"][k] = r["memory"][k] * c
    r["roofline"] = roofline.roofline_terms(
        r["flops_per_device"], r["bytes_per_device"],
        r["collective_wire_bytes"], c)
    r["raw_stats"] = True
    p.write_text(json.dumps(r, indent=1))
print("fixed")
