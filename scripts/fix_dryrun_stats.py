"""One-shot fixup: early sweep records divided per-device stats by chips;
multiply back and recompute roofline terms (idempotent via raw_stats flag).

  python scripts/fix_dryrun_stats.py [--out results/dryrun]

--out defaults to the benchmarks' shared results root (benchmarks.common
.DRYRUN), the same directory launch/dryrun.py writes to.
"""
import argparse
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))        # benchmarks.common
sys.path.insert(0, str(_ROOT / "src"))  # repro

import json  # noqa: E402

from benchmarks.common import DRYRUN  # noqa: E402
from repro.launch import roofline  # noqa: E402


def main():
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--out", default=str(DRYRUN),
                    help="dry-run results directory to fix in place "
                         f"(default: {DRYRUN})")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    fixed = skipped = 0
    for p in sorted(out.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped") or r.get("raw_stats"):
            skipped += 1
            continue
        c = r["chips"]
        r["flops_per_device"] = r["flops_per_device"] * c
        r["bytes_per_device"] = r["bytes_per_device"] * c
        for k in ("argument_bytes", "output_bytes", "temp_bytes",
                  "alias_bytes", "peak_bytes"):
            if r["memory"].get(k) is not None:
                r["memory"][k] = r["memory"][k] * c
        r["roofline"] = roofline.roofline_terms(
            r["flops_per_device"], r["bytes_per_device"],
            r["collective_wire_bytes"], c)
        r["raw_stats"] = True
        p.write_text(json.dumps(r, indent=1))
        fixed += 1
    print(f"fixed {fixed} record(s) in {out} ({skipped} already raw/skipped)")


if __name__ == "__main__":
    main()
