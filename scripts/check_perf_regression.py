"""Perf-trajectory gate: diff fresh benchmark rows against committed baselines.

Compares ``results/bench/<section>.json`` (written by a benchmark run that
just happened, e.g. the CI benchmarks-smoke job) against the committed
``results/bench/<section>_baseline.json`` snapshot:

* every baseline row must still exist (a vanished row is a coverage
  regression, not a perf win);
* each row's ``us_per_call`` may not exceed baseline * ``--tol`` (the
  default tolerance is deliberately loose — shared CPU CI runners are
  noisy; the gate catches order-of-magnitude regressions like losing the
  kernel fusion or the bucket scan, not 20% jitter).  Rows whose baseline
  is ~0 us (pure derived/ratio rows) are skipped for the time check;
* fig7b mesh rows carry ``emulator_overhead_ratio=`` in their derived
  field — the mesh-vs-behavioral step-time ratio.  Fresh ratios must stay
  under ``--ratio-cap`` (the tentpole's <= ~2x bar, with tolerance
  headroom) for the noise-free mesh rows;
* overlap rows (benchmarks.overlap) are held to the streaming engine's
  two invariants regardless of CI wall-clock noise: ``wire_ratio=``
  (overlap-on / overlap-off modeled time_on_wire) must stay <= 1.0, and
  ``losses_match=`` must stay 1 — streaming may never cost wire time or
  perturb numerics;
* elastic rows (benchmarks.elastic, gated via ``--sections elastic`` in
  the CI chaos-smoke step) must keep ``recovered=`` at 1 — the
  SIGKILL'd 4-process cascade run re-derived the shrunk topology and
  its post-recovery loss kept descending;
* serving rows (benchmarks.serve_throughput) must keep
  ``speedup_vs_sequential=`` above 1.0 (continuous batching beats
  sequential decode) and ``paged_vs_gather=`` at or above the 0.9 noise
  floor (the paged decode backend never loses to the gather path it
  replaces — see SERVE_GATED below for why the floor is not 1.0).

  PYTHONPATH=src python scripts/check_perf_regression.py \
      [--sections mesh_emulation,fig7b,serve_throughput,overlap] \
      [--tol 4.0] [--ratio-cap 2.0]

Refresh a baseline by re-running the benchmark on a quiet machine and
copying ``results/bench/<section>.json`` over the ``_baseline`` file.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH = _ROOT / "results" / "bench"

# fig7b rows the --ratio-cap binds: the noise-free mesh row of llama8L
# in the paper's H100 setting — the smoke arch the <= ~2x bar is stated
# against (measured 1.38x).  The v5e re-parameterization and resnet50
# divide the same measured emulator cost by a much smaller modeled
# compute term, so their ratios are compute-shape artifacts and stay
# informational.
RATIO_GATED = re.compile(r"^fig7b\.H100\.llama8L\.mesh$")

# overlap rows: modeled-wire-time and numeric-identity invariants
OVERLAP_GATED = re.compile(r"^overlap\.")

# elastic rows (benchmarks.elastic): the chaos run must RECOVER — the
# survivors re-derived the shrunk topology and the post-recovery loss
# kept descending.  Timing is not gated (us_per_call ~ 0 skips it);
# recovery is binary.
ELASTIC_GATED = re.compile(r"^elastic\.")

# serving rows: continuous batching must keep beating sequential decode
# (speedup_vs_sequential > 1), and the 'paged' decode backend may not
# lose to the gather path it replaces.  On CPU CI 'paged' dispatches to
# the identical gather XLA program (kernels.paged_attention.use_kernel),
# so paged_vs_gather is runner noise around 1.0 — the 0.9 floor catches
# a real dispatch regression (paged silently running a slower program),
# not jitter; on TPU the same floor demands the kernel at least match
# the gather copy it removes.
SERVE_GATED = re.compile(r"^serve_throughput\.(continuous|decode_paged)$")
PAGED_VS_GATHER_FLOOR = 0.9


def load_rows(path: pathlib.Path) -> dict:
    with open(path) as f:
        return {row["name"]: row for row in json.load(f)}


def derived_field(row: dict, key: str) -> float | None:
    m = re.search(rf"{key}=([-0-9.e+]+)", row.get("derived", ""))
    return float(m.group(1)) if m else None


def check_section(section: str, tol: float, ratio_cap: float) -> list:
    fresh_p = BENCH / f"{section}.json"
    base_p = BENCH / f"{section}_baseline.json"
    if not base_p.exists():
        return [f"{section}: missing baseline {base_p} (run the benchmark "
                f"and copy {section}.json to {section}_baseline.json)"]
    if not fresh_p.exists():
        return [f"{section}: no fresh {fresh_p} — run the benchmark before "
                f"the gate"]
    fresh, base = load_rows(fresh_p), load_rows(base_p)
    errors = []
    for name, brow in base.items():
        frow = fresh.get(name)
        if frow is None:
            errors.append(f"{section}: baseline row {name!r} vanished")
            continue
        b_us, f_us = brow["us_per_call"], frow["us_per_call"]
        if b_us > 1.0 and f_us > b_us * tol:
            errors.append(
                f"{section}: {name} regressed {f_us:.0f}us vs baseline "
                f"{b_us:.0f}us (tol {tol:g}x)")
    for name, frow in fresh.items():
        if RATIO_GATED.match(name):
            ratio = derived_field(frow, "emulator_overhead_ratio")
            if ratio is not None and ratio > ratio_cap:
                errors.append(
                    f"{section}: {name} emulator_overhead_ratio={ratio:.2f} "
                    f"exceeds the {ratio_cap:g}x mesh-vs-behavioral cap")
        if OVERLAP_GATED.match(name):
            wr = derived_field(frow, "wire_ratio")
            if wr is not None and wr > 1.0:
                errors.append(
                    f"{section}: {name} wire_ratio={wr:.3f} > 1.0 — "
                    f"overlap-on modeled time_on_wire exceeds overlap-off")
            lm = derived_field(frow, "losses_match")
            if lm is not None and lm != 1:
                errors.append(
                    f"{section}: {name} losses_match={lm:g} — the "
                    f"streaming engine's losses diverged from the barrier "
                    f"path")
        if SERVE_GATED.match(name):
            sp = derived_field(frow, "speedup_vs_sequential")
            if sp is not None and sp <= 1.0:
                errors.append(
                    f"{section}: {name} speedup_vs_sequential={sp:g} <= "
                    f"1.0 — continuous batching stopped beating sequential "
                    f"decode")
            pg = derived_field(frow, "paged_vs_gather")
            if pg is not None and pg < PAGED_VS_GATHER_FLOOR:
                errors.append(
                    f"{section}: {name} paged_vs_gather={pg:g} < "
                    f"{PAGED_VS_GATHER_FLOOR:g} — the paged decode backend "
                    f"lost to the gather path it replaces")
        if ELASTIC_GATED.match(name):
            rec = derived_field(frow, "recovered")
            if rec is not None and rec != 1:
                errors.append(
                    f"{section}: {name} recovered={rec:g} — the chaos run "
                    f"did not survive the SIGKILL (no topology "
                    f"re-derivation or the post-recovery loss stalled)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--sections",
                    default="mesh_emulation,fig7b,serve_throughput,overlap",
                    help="comma-separated baseline sections to gate")
    ap.add_argument("--tol", type=float, default=4.0,
                    help="allowed fresh/baseline us_per_call ratio "
                         "(loose: CI runners are noisy)")
    ap.add_argument("--ratio-cap", type=float, default=2.0,
                    help="max fig7b mesh emulator_overhead_ratio")
    args = ap.parse_args()
    errors = []
    for section in args.sections.split(","):
        errors += check_section(section.strip(), args.tol, args.ratio_cap)
    for e in errors:
        print(f"PERF REGRESSION: {e}", file=sys.stderr)
    if not errors:
        print(f"perf gate OK: {args.sections} within {args.tol:g}x of "
              f"baseline, mesh overhead ratio <= {args.ratio_cap:g}x")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
