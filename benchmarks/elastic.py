"""Elastic-recovery benchmark: the chaos scenario as a gated metric.

Runs ``repro.elastic.chaos.run_chaos`` — four worker processes over a
(pods=2, dp=2) cascade base topology, one SIGKILLed mid-run — and emits
one row with the recovery facts the perf gate holds
(scripts/check_perf_regression.py, section ``elastic``):

  us_per_call      0.0 (this is a correctness/recovery row, not a timing
                   row — the time check skips ~0 baselines)
  recovered        1 iff the survivors re-derived a smaller topology AND
                   the post-recovery losses kept descending (gated == 1)
  old_topo/new_topo  the mesh shapes either side of the membership change
  new_n/new_n1     the re-derived collective size and level-1 split (the
                   1/N carry grid and bytes_on_wire follow from these)
  wire_bytes_ratio new/old modeled bytes_on_wire — shrinking the world
                   must shrink the modeled wire cost
  drain_s          seconds between the monitor detecting the change and
                   the epoch draining to its re-derivation point
  recover_s        SIGKILL -> run-complete wall time
  loss_first/last  loss trajectory endpoints across BOTH epochs

Rows mirror to results/bench/elastic.json; the committed
results/bench/elastic_baseline.json is the regression reference.

    PYTHONPATH=src python -m benchmarks.elastic [--smoke] [--full]
"""
from __future__ import annotations

import argparse
import shutil
import sys
import tempfile

from .common import emit, flush_json

sys.path.insert(0, "src")


def _shape(t) -> str:
    return "x".join(str(x) for x in t)


def main(full: bool = False, smoke: bool = False):
    try:
        _run(full=full, smoke=smoke)
    finally:
        flush_json("elastic")


def _run(full: bool, smoke: bool):
    from repro.elastic.chaos import run_chaos

    steps = 24 if full else 12
    workdir = tempfile.mkdtemp(prefix="elastic_chaos_")
    try:
        result = run_chaos(workdir, n_workers=4, kill_index=3,
                           kill_after_step=0, steps=steps)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    events = result.get("events", [])
    history = result.get("history", [])
    losses = [r["loss"] for r in history]
    ev = events[0] if events else {}
    post = ([r["loss"] for r in history if r["step"] >= ev["step"]]
            if ev else [])
    recovered = int(
        bool(ev)
        and ev.get("new_topology") == [1, 2]       # (pods, dp): one pod left
        and not result.get("error")
        and len(post) >= 2 and post[-1] < post[0]
        and all(l == l and abs(l) != float("inf") for l in losses))
    old_topo = _shape(ev.get("old_topology", ["?"]))
    new_topo = _shape(ev.get("new_topology", ["?"]))
    ratio = ""
    if ev:
        from repro.api import MeshSpec, RunSpec, SyncConfig, build
        base = RunSpec(arch="minitron_4b", smoke=True,
                       mesh=MeshSpec(pods=2, dp=2),
                       sync=SyncConfig(mode="cascade"))
        import dataclasses
        shrunk = dataclasses.replace(
            base, mesh=dataclasses.replace(base.mesh, pods=1))
        ratio = (f" wire_bytes_ratio="
                 f"{build.modeled_bytes_on_wire(shrunk) / build.modeled_bytes_on_wire(base):.3f}")
    emit("elastic.chaos.cascade", 0.0,
         f"recovered={recovered} old_topo={old_topo} new_topo={new_topo} "
         f"new_n={ev.get('n', 0)} new_n1={ev.get('n1', 0)} "
         f"drain_s={ev.get('drain_s', -1)} "
         f"recover_s={result.get('kill', {}).get('recover_s', -1)} "
         f"loss_first={losses[0] if losses else -1} "
         f"loss_last={losses[-1] if losses else -1}{ratio}")
    if not recovered:
        raise RuntimeError(
            f"chaos run did not recover: events={events!r} "
            f"losses={losses!r} error={result.get('error')!r}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="shorter run (the chaos scenario is already the "
                         "smoke arch)")
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke)
