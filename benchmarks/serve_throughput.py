"""Serving-tier throughput: continuous batching vs sequential decode.

The workload is a queue of requests with one prompt length but staggered
generation budgets (real serving traffic: arrivals overlap, completions
do not line up).  Two ways to drain it:

  sequential   ServeSession.generate, one request at a time — the
               pre-PR-7 serving story.  Every decode step advances ONE
               sequence.
  continuous   ServeEngine — every decode step advances every active
               sequence (paged KV pool, admit/retire between steps), so
               the per-step program launch and weight traffic are
               amortized over up to ``max_active`` sequences.

Both paths run the same greedy math (tests/test_serving.py proves the
outputs identical), so the ratio is pure batching efficiency.  All jit
programs are warmed before timing: the engine drains a full throwaway
workload first, which visits every power-of-two occupancy bucket the
timed run can touch.  Rows mirror to results/bench/serve_throughput.json
(CI artifact + perf-regression baseline).

The decode_gather / decode_paged pair times the engine under both
``ServeConfig.decode_backend`` values at max_active=8 and reports
per-token latency percentiles (one engine.step() == one token for every
active sequence, so step latency IS the inter-token latency a client
sees).  On TPU 'paged' runs the Pallas in-place kernel
(kernels.paged_attention) and the ratio measures skipping the
page-gather copy; on CPU CI 'paged' dispatches to the identical gather
XLA program, so paged_vs_gather sits at ~1.0 and the perf gate's floor
only catches a real dispatch regression.

    PYTHONPATH=src python -m benchmarks.serve_throughput [--full] [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from .common import emit, flush_json

PROMPT_LEN = 12
MAX_SEQ = 64


def _spec(max_active: int = 8):
    from repro.api import RunSpec, ServeConfig
    return dataclasses.replace(
        RunSpec(arch="minitron_4b", smoke=True),
        serve=ServeConfig(page_size=8, max_active=max_active,
                          max_seq=MAX_SEQ, max_queue=64))


def _workload(n_seqs: int, vocab: int):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, vocab, (PROMPT_LEN,)).tolist()
               for _ in range(n_seqs)]
    budgets = [6 + (i % 5) * 3 for i in range(n_seqs)]   # 6..18 tokens
    return prompts, budgets


def _drain_staggered(engine, prompts, budgets, step_times=None):
    """Submit ``max_active`` requests up front, then one more per decode
    step (staggered arrivals), and run to empty.  Returns tokens emitted;
    appends each engine.step() wall time to ``step_times`` when given."""
    arrivals = list(zip(prompts, budgets))
    head = arrivals[:engine.scfg.max_active]
    rest = arrivals[len(head):]
    rids = [engine.submit(p, b) for p, b in head]
    while engine.has_work() or rest:
        if rest:
            p, b = rest.pop(0)
            rids.append(engine.submit(p, b))
        t0 = time.time()
        engine.step()
        if step_times is not None:
            step_times.append(time.time() - t0)
    return sum(len(engine.results[r]) for r in rids)


def _time_continuous(session, prompts, budgets, max_active: int):
    eng = session.engine() if max_active == session.spec.serve.max_active \
        else _engine_with(session, max_active)
    _drain_staggered(eng, prompts, budgets)       # warmup: compiles every
    eng.results.clear()                           # bucket + prefill shape
    t0 = time.time()
    toks = _drain_staggered(eng, prompts, budgets)
    return toks, time.time() - t0, eng.max_observed_active


def _engine_with(session, max_active: int):
    from repro.serving.engine import ServeEngine
    spec = dataclasses.replace(
        session.spec,
        serve=dataclasses.replace(session.spec.serve, max_active=max_active))
    return ServeEngine(spec, params=session.params)


def _engine_backend(session, backend: str):
    from repro.serving.engine import ServeEngine
    spec = dataclasses.replace(
        session.spec,
        serve=dataclasses.replace(session.spec.serve,
                                  decode_backend=backend))
    return ServeEngine(spec, params=session.params)


def main(full: bool = False, smoke: bool = False):
    try:
        _run(full=full, smoke=smoke)
    finally:
        flush_json("serve_throughput")


def _run(full: bool, smoke: bool):
    from repro.api import ServeSession

    spec = _spec()
    session = ServeSession(spec)
    n_seqs = 8 if smoke else 16
    prompts, budgets = _workload(n_seqs, session.cfg.vocab)

    # ---- sequential baseline (one request at a time, static batch of 1)
    session.generate(np.asarray([prompts[0]]), gen_len=max(budgets),
                     max_seq=MAX_SEQ)             # warmup: prefill + decode
    t0 = time.time()
    seq_toks = 0
    for p, b in zip(prompts, budgets):
        out = session.generate(np.asarray([p]), gen_len=b, max_seq=MAX_SEQ)
        seq_toks += out.shape[1]
    seq_dt = time.time() - t0
    emit("serve_throughput.sequential", 1e6 * seq_dt / seq_toks,
         f"tok_s={seq_toks / seq_dt:.1f} n_seqs={n_seqs} tokens={seq_toks}")

    # ---- continuous batching through the paged-KV engine
    cont_toks, cont_dt, peak = _time_continuous(session, prompts, budgets,
                                                spec.serve.max_active)
    assert cont_toks == seq_toks, (cont_toks, seq_toks)
    speedup = seq_dt / cont_dt
    emit("serve_throughput.continuous", 1e6 * cont_dt / cont_toks,
         f"tok_s={cont_toks / cont_dt:.1f} n_seqs={n_seqs} "
         f"tokens={cont_toks} max_active={spec.serve.max_active} "
         f"peak_concurrency={peak} speedup_vs_sequential={speedup:.2f}")
    if speedup <= 1.0:
        raise RuntimeError(
            f"continuous batching ({cont_toks / cont_dt:.1f} tok/s) did not "
            f"beat sequential decode ({seq_toks / seq_dt:.1f} tok/s)")

    # ---- decode backend: gather vs paged, with per-token latency tails
    tok_s, lat = {}, {}
    for backend in ("gather", "paged"):
        eng = _engine_backend(session, backend)
        _drain_staggered(eng, prompts, budgets)   # warm every bucket/shape
        eng.results.clear()
        times: list = []
        t0 = time.time()
        toks = _drain_staggered(eng, prompts, budgets, step_times=times)
        dt = time.time() - t0
        assert toks == seq_toks, (backend, toks, seq_toks)
        tok_s[backend] = toks / dt
        lat[backend] = (float(np.percentile(times, 50)),
                        float(np.percentile(times, 99)))
    ratio = tok_s["paged"] / tok_s["gather"]
    for backend in ("gather", "paged"):
        extra = f" paged_vs_gather={ratio:.2f}" if backend == "paged" else ""
        emit(f"serve_throughput.decode_{backend}", 1e6 / tok_s[backend],
             f"tok_s={tok_s[backend]:.1f} n_seqs={n_seqs} max_active="
             f"{spec.serve.max_active} tok_lat_p50_ms="
             f"{1e3 * lat[backend][0]:.2f} tok_lat_p99_ms="
             f"{1e3 * lat[backend][1]:.2f}" + extra)

    if full:
        # concurrency scaling: same workload, shrinking slot counts
        for ma in (1, 2, 4):
            toks, dt, peak = _time_continuous(session, prompts, budgets, ma)
            emit(f"serve_throughput.continuous_ma{ma}", 1e6 * dt / toks,
                 f"tok_s={toks / dt:.1f} n_seqs={n_seqs} max_active={ma} "
                 f"peak_concurrency={peak}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="add the max_active concurrency sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller request count (CI)")
    args = ap.parse_args()
    try:
        main(full=args.full, smoke=args.smoke)
    except RuntimeError as e:
        raise SystemExit(str(e))
