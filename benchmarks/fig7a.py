"""Paper Fig. 7a: end-to-end training with the OptINC collective, with and
without Table-II error injection, vs the exact baseline.

Budgeted reproduction: the paper trains ResNet50/CIFAR-100 for 300 epochs
and LLaMA-8L/Wikipedia-1B for 50k steps on A100s; this container runs
shortened versions of BOTH models on deterministic synthetic streams and
compares final losses across sync modes. The paper's claim shape —
OptINC quantization costs almost nothing; Table-II error injection costs
slightly more but stays in range — is what we check.

The ``optinc_b2_{behavioral,mesh}`` pair puts the emulated hardware in
the loop: at bits=2 the built-in exact identity ONN resolves without
training, so ``--fidelity mesh`` runs the fast Givens-layer emulator
(repro.photonics.mesh) inside every jitted step and must reproduce the
behavioral losses EXACTLY (same RNG, bit-exact collective) — the loop
below ASSERTS that equality.  On TPU a third ``optinc_b2_mesh_pallas``
row runs the fused kernel (``--mesh-backend pallas``) under the same
equality gate; off-TPU the kernel interprets (far too slow for
gradient-sized batches — tests/test_photonics.py carries the
multi-device pallas bit-exactness gate there instead).

``--smoke`` (CI) runs only the short behavioral LM rows.
"""
from __future__ import annotations

import argparse
import json

from .common import emit, flush_json, run_subprocess

LM_RUN = """
import json, io, contextlib
import repro.launch.train as T
buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    T.main(["--arch", "paper_llama", "--smoke-config", "--sync", "{sync}",
            "--steps", "{steps}", "--global-batch", "8", "--seq-len", "128",
            "--lr", "1e-3", "--mesh", "1x1"{extra}])
recs = [json.loads(l) for l in buf.getvalue().splitlines() if l.startswith("{{")]
last = sum(r["loss"] for r in recs[-5:]) / 5
first = sum(r["loss"] for r in recs[:5]) / 5
print(json.dumps({{"first": first, "last": last}}))
"""

RESNET_RUN = """
import json
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.models import resnet
from repro.data.pipeline import synthetic_images
from repro.collectives import SyncConfig, sync_gradients
from repro.launch.mesh import make_mesh
from jax.sharding import PartitionSpec as P

mesh = make_mesh((1,), ("data",))
params = resnet.init_params(jax.random.PRNGKey(0))
sync = SyncConfig(mode="{sync}", axes=("data",), bits=8, block=2048,
                  error_layers={err})

def step(params, images, labels, key):
    (l, acc), g = jax.value_and_grad(resnet.loss_fn, has_aux=True)(
        params, images, labels)
    g, _ = sync_gradients(g, sync, key, None)
    params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    return params, l, acc

sfn = jax.jit(jax.shard_map(step, mesh=mesh,
    in_specs=(P(), P("data"), P("data"), P()),
    out_specs=(P(), P(), P()), check_vma=False))
losses = []
key = jax.random.PRNGKey(1)
for s in range({steps}):
    imgs, labels = synthetic_images(s, 16)
    key, sub = jax.random.split(key)
    params, l, acc = sfn(params, jnp.asarray(imgs), jnp.asarray(labels), sub)
    losses.append(float(l))
print(json.dumps({{"first": sum(losses[:3])/3, "last": sum(losses[-3:])/3}}))
"""


def main(full: bool = False, smoke: bool = False):
    try:
        _run(full=full, smoke=smoke)
    finally:
        flush_json("fig7a")


def _tpu_children() -> bool:
    """Will the LM_RUN subprocesses run on TPU?  Probed in a subprocess
    with the SAME env run_subprocess gives the training rows (importing
    jax here would take the TPU lock and break every child on exactly
    the platform the probe exists to detect).  Note run_subprocess pins
    children to cpu when JAX_PLATFORMS is unset, so on a TPU VM the
    pallas row requires an explicit JAX_PLATFORMS=tpu — matching where
    the children actually run, never the parent's hardware."""
    try:
        out = run_subprocess("import jax; print(jax.default_backend())")
        return out.strip().splitlines()[-1] == "tpu"
    except Exception:
        return False


def _run(full: bool, smoke: bool):
    lm_steps = 60 if full else (6 if smoke else 25)
    rn_steps = 30 if full else 10
    runs = [("baseline_psum", "psum", ""),
            ("optinc_ideal", "optinc", "")]
    if not smoke:
        runs += [("optinc_err3456", "optinc",
                  ', "--error-layers", "3,4,5,6"'),
                 # hardware-in-the-loop pair: bit-exact against each other
                 # (behavioral == mesh emulator; asserted below)
                 ("optinc_b2_behavioral", "optinc", ', "--bits", "2"'),
                 ("optinc_b2_mesh", "optinc",
                  ', "--bits", "2", "--fidelity", "mesh"')]
        if _tpu_children():
            # interpret-mode pallas is minutes/step at gradient batch
            # sizes; the fused-kernel row only makes sense compiled
            runs.append(("optinc_b2_mesh_pallas", "optinc",
                         ', "--bits", "2", "--fidelity", "mesh", '
                         '"--mesh-backend", "pallas"'))
    losses = {}
    for name, sync, extra in runs:
        out = run_subprocess(LM_RUN.format(sync=sync, steps=lm_steps,
                                           extra=extra), timeout=3000)
        rec = json.loads(out.strip().splitlines()[-1])
        losses[name] = rec
        emit(f"fig7a.llama.{name}", 0.0,
             f"loss_first={rec['first']:.4f} loss_last={rec['last']:.4f} "
             f"steps={lm_steps}")
    # the advertised hardware-in-the-loop equality is a gate, not prose.
    # Exactness holds for the pallas row too, even compiled: at bits=2 /
    # N=1 the exact-identity ONN's analog outputs are small integers
    # represented exactly in f32, so no readout sits near a PAM4 decision
    # boundary where executor rounding could flip it (the trained-B=8
    # harness, whose readouts DO approach boundaries, budgets tolerance
    # instead — benchmarks/trained_onn.py).
    beh = losses.get("optinc_b2_behavioral")
    for name in ("optinc_b2_mesh", "optinc_b2_mesh_pallas"):
        if beh is not None and name in losses and losses[name] != beh:
            raise RuntimeError(
                f"{name} losses {losses[name]} diverged from behavioral "
                f"{beh} — the fidelity cascade is no longer bit-exact")
    if smoke:
        return
    for name, sync, err in [("baseline_psum", "psum", "()"),
                            ("optinc_err3456", "optinc", "(3,4,5,6)")]:
        out = run_subprocess(RESNET_RUN.format(sync=sync, err=err,
                                               steps=rn_steps), timeout=3000)
        rec = json.loads(out.strip().splitlines()[-1])
        emit(f"fig7a.resnet50.{name}", 0.0,
             f"loss_first={rec['first']:.4f} loss_last={rec['last']:.4f} "
             f"steps={rn_steps}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="short behavioral LM rows only (CI)")
    args = ap.parse_args()
    try:
        main(full=args.full, smoke=args.smoke)
    except RuntimeError as e:
        raise SystemExit(str(e))
