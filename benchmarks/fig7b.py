"""Paper Fig. 7b: modeled per-step latency, ring vs the OptINC fidelity sweep.

The paper's setting: H100-class GPUs, 60 TFLOP/s effective x 0.6
utilization, 8 full-duplex 800 Gb/s transceivers, 4 servers.  We reproduce
that model, re-parameterize it for TPU v5e (197 TFLOP/s bf16, 4x50 GB/s
ICI links) — the target of this framework — and extend the original
ring-vs-behavioral contrast into the full photonic fidelity sweep:

  behavioral   the modeled OptINC wire time only (the physical fabric
               computes Q(mean) at line rate — no emulator on the host)
  onn          + the MEASURED cost of running the dense in-network ONN
               forward pass over every synced gradient element
  mesh         + the measured cost of the phase-programmed MZI mesh
               emulator (xla executor), noise off
  mesh_noise   same, with the PhaseNoise model on (theta drift + shot
               noise drawn per apply)

The emulator costs are measured the same way ``mesh_emulation`` times the
executors (jit + block_until_ready around ``ONNModule.symbols`` on a
gradient-sized code batch, built-in exact ONN at bits=2 so CI needs no
trained params) and scaled to the model's gradient element count — i.e.
the real accuracy/latency trade-off of hardware-in-the-loop training as
a benchmark row.  Rows mirror to results/bench/fig7b.json (CI artifact).

``--noise-sweep`` runs a different experiment: end-to-end smoke training
at ``--fidelity mesh`` across a grid of PhaseNoise settings
(theta_drift_std x shot_noise_std), reporting first/last losses per
point — does the emulated hardware's analog imperfection actually move
the training trajectory, and when?  Rows go to
results/bench/noise_sweep.json.

    PYTHONPATH=src python -m benchmarks.fig7b [--full] [--smoke]
    PYTHONPATH=src python -m benchmarks.fig7b --noise-sweep [--full]
"""
from __future__ import annotations

import argparse
import json

from .common import emit, flush_json, run_subprocess, timed

GPU_FLOPS = 60e12 * 0.6
GPU_BW = 8 * 800e9 / 8          # bytes/s aggregate (800 Gb/s x 8 lanes)
V5E_FLOPS = 197e12 * 0.6
V5E_BW = 4 * 50e9

MODELS = {
    # (flops per sample fwd+bwd, gradient bytes, batch per step)
    # ResNet50 @ CIFAR-100: ~3.9 GFLOP fwd x3; grads 25.6M params x 4B
    "resnet50": (3 * 3.9e9, 25.6e6 * 4, 256),
    # paper LLaMA-8L d384: ~43M params, seq 1024
    "llama8L": (6 * 43e6 * 1024, 43e6 * 4, 32),
}

# the sweep: (row suffix, fidelity, noise on)
SWEEP = [("behavioral", "behavioral", False),
         ("onn", "onn", False),
         ("mesh", "mesh", False),
         ("mesh_noise", "mesh", True)]

NOISE_STD = (0.02, 0.01)        # (theta_drift_std, shot_noise_std)


def breakdown(flops, grad_bytes, batch, n, peak, bw):
    compute = batch * flops / peak
    ring = 2 * (n - 1) / n * grad_bytes / bw
    optinc = 1.0 * grad_bytes / bw
    return compute, ring, optinc


def measure_emulator_us(batch: int) -> dict:
    """us per gradient ELEMENT of the emulated fabric, per sweep row.

    ``behavioral`` costs nothing on the host (the modeled fabric does the
    math); the others time one jitted ``symbols`` pass at bits=2 over a
    ``batch``-element code block — ``mesh_emulation``-style timing —
    and amortize.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.photonics import PhaseNoise, PhotonicsConfig, get_module

    module = get_module(PhotonicsConfig(fidelity="mesh"), 2, 4)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 9, size=(batch, 1)).astype(np.float32)
                    / 4.0)
    noise = PhaseNoise(*NOISE_STD)

    # the key is built OUTSIDE the timed region: a real training step
    # derives one step key for millions of synced elements, so folding
    # PRNGKey construction (~100 us of host work) into every timed call
    # would inflate the amortized per-element cost ~7x at smoke batches
    key = jax.random.PRNGKey(0)

    def block(fn):
        # the inputs (codes AND key) are traced arguments — a nullary
        # closure would let XLA constant-fold the whole forward pass and
        # time nothing but dispatch
        jitted = jax.jit(fn)
        _, us = timed(lambda: jax.block_until_ready(jitted(a, key)))
        return us

    per_elem = {"behavioral": 0.0}
    per_elem["onn"] = block(
        lambda x, k: module.symbols(x, fidelity="onn")) / batch
    per_elem["mesh"] = block(
        lambda x, k: module.symbols(x, fidelity="mesh")) / batch
    per_elem["mesh_noise"] = block(
        lambda x, k: module.symbols(x, fidelity="mesh", noise=noise,
                                    key=k)) / batch
    return per_elem


NOISE_RUN = """
import json, io, contextlib
import repro.launch.train as T
buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    T.main(["--arch", "paper_llama", "--smoke-config", "--sync", "optinc",
            "--bits", "2", "--fidelity", "mesh", "--steps", "{steps}",
            "--global-batch", "8", "--seq-len", "128", "--lr", "1e-3",
            "--mesh", "1x1", "--theta-drift-std", "{td}",
            "--shot-noise-std", "{sn}"])
recs = [json.loads(l) for l in buf.getvalue().splitlines() if l.startswith("{{")]
last = sum(r["loss"] for r in recs[-3:]) / 3
first = sum(r["loss"] for r in recs[:3]) / 3
print(json.dumps({{"first": first, "last": last}}))
"""

# (theta_drift_std, shot_noise_std): clean reference, each mechanism
# alone at the paper-plausible magnitude, combined, and 5x combined
NOISE_GRID = [(0.0, 0.0), (0.02, 0.0), (0.0, 0.01), (0.02, 0.01),
              (0.1, 0.05)]


def noise_sweep(full: bool = False):
    """PhaseNoise-vs-training-loss sweep at --fidelity mesh (end-to-end:
    the noisy MZI emulator runs inside every jitted training step)."""
    try:
        _noise_sweep(full)
    finally:
        flush_json("noise_sweep")


def _noise_sweep(full: bool):
    steps = 25 if full else 8
    clean_last = None
    for td, sn in NOISE_GRID:
        out = run_subprocess(NOISE_RUN.format(steps=steps, td=td, sn=sn),
                             timeout=3000)
        rec = json.loads(out.strip().splitlines()[-1])
        if (td, sn) == (0.0, 0.0):
            clean_last = rec["last"]
        delta = rec["last"] - clean_last if clean_last is not None else 0.0
        emit(f"noise_sweep.td{td:g}_sn{sn:g}", 0.0,
             f"theta_drift_std={td:g} shot_noise_std={sn:g} "
             f"loss_first={rec['first']:.4f} loss_last={rec['last']:.4f} "
             f"loss_delta_vs_clean={delta:.4f} steps={steps}")


def main(full: bool = False, smoke: bool = False):
    try:
        _run(full=full, smoke=smoke)
    finally:
        flush_json("fig7b")


def _run(full: bool, smoke: bool):
    # a real mesh-fidelity sync applies the ONN over ~1M-element buckets
    # (4 MiB f32), so even the smoke batch must be large enough that the
    # per-call jit dispatch overhead (~100 us on CPU CI) does not swamp
    # the amortized per-element cost it is scaled to (measured: per-elem
    # cost drops ~2x from 32k to 128k and flattens past that)
    batch = 131072 if smoke else (262144 if full else 131072)
    per_elem_us = measure_emulator_us(batch)
    n = 4
    for hw, (peak, bw) in (("H100", (GPU_FLOPS, GPU_BW)),
                           ("v5e", (V5E_FLOPS, V5E_BW))):
        for name, (flops, gbytes, mbatch) in MODELS.items():
            comp, ring, opt = breakdown(flops, gbytes, mbatch, n, peak, bw)
            total_ring = comp + ring
            total_behavioral = comp + opt        # emulator-free step time
            for row, fidelity, noisy in SWEEP:
                emu_s = per_elem_us[row] * (gbytes / 4.0) / 1e6
                total = comp + opt + emu_s
                # numeric field: the row's TOTAL per-step emulator cost in
                # us — per-element costs are sub-0.1 us and would round
                # to 0.0 in the CSV/JSON, losing the trajectory signal.
                # emulator_overhead_ratio is the perf-trajectory gate: how
                # much slower a step at this fidelity runs than the
                # behavioral (no-emulator) step — the tentpole bar is the
                # mesh row staying <= ~2x
                emit(f"fig7b.{hw}.{name}.{row}", emu_s * 1e6,
                     f"fidelity={fidelity} noise={int(noisy)} "
                     f"compute_ms={comp * 1e3:.2f} "
                     f"ring_comm_ms={ring * 1e3:.2f} "
                     f"optinc_comm_ms={opt * 1e3:.2f} "
                     f"emulator_ms={emu_s * 1e3:.2f} "
                     f"emulator_overhead_ratio="
                     f"{total / total_behavioral:.3f} "
                     f"latency_reduction={1 - total / total_ring:.3f}")
            # streaming-engine wire model (EXPERIMENTS.md §Overlap): the
            # optinc fabric-occupancy seconds per step with and without
            # backward/comm overlap — reconfiguration pipelining on top
            # of the byte reduction the rows above already price in
            from repro.collectives import get_backend
            nb_bf16 = gbytes / 2.0       # MODELS gbytes are f32 bytes
            t_off = get_backend("optinc").time_on_wire(
                nb_bf16, n, 8, overlap=False)
            t_on = get_backend("optinc").time_on_wire(
                nb_bf16, n, 8, overlap=True)
            emit(f"fig7b.{hw}.{name}.overlap", t_on * 1e6,
                 f"time_on_wire_off_us={t_off * 1e6:.1f} "
                 f"time_on_wire_on_us={t_on * 1e6:.1f} "
                 f"wire_ratio={t_on / t_off:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="small measurement batch (CI)")
    ap.add_argument("--noise-sweep", action="store_true",
                    help="PhaseNoise grid vs smoke-training loss at "
                         "--fidelity mesh (rows to noise_sweep.json)")
    args = ap.parse_args()
    if args.noise_sweep:
        noise_sweep(full=args.full)
    else:
        main(full=args.full, smoke=args.smoke)
