"""Paper Fig. 7b: modeled per-step latency breakdown, ring vs OptINC.

The paper's setting: H100-class GPUs, 60 TFLOP/s effective x 0.6
utilization, 8 full-duplex 800 Gb/s transceivers, 4 servers. We reproduce
that model and additionally re-parameterize it for TPU v5e (197 TFLOP/s
bf16, 4x50 GB/s ICI links) — the target of this framework.
"""
from __future__ import annotations

from .common import emit

GPU_FLOPS = 60e12 * 0.6
GPU_BW = 8 * 800e9 / 8          # bytes/s aggregate (800 Gb/s x 8 lanes)
V5E_FLOPS = 197e12 * 0.6
V5E_BW = 4 * 50e9

MODELS = {
    # (flops per sample fwd+bwd, gradient bytes, batch per step)
    # ResNet50 @ CIFAR-100: ~3.9 GFLOP fwd x3; grads 25.6M params x 4B
    "resnet50": (3 * 3.9e9, 25.6e6 * 4, 256),
    # paper LLaMA-8L d384: ~43M params, seq 1024
    "llama8L": (6 * 43e6 * 1024, 43e6 * 4, 32),
}


def breakdown(flops, grad_bytes, batch, n, peak, bw):
    compute = batch * flops / peak
    ring = 2 * (n - 1) / n * grad_bytes / bw
    optinc = 1.0 * grad_bytes / bw
    return compute, ring, optinc


def main(full: bool = False):
    for hw, (peak, bw) in (("H100", (GPU_FLOPS, GPU_BW)),
                           ("v5e", (V5E_FLOPS, V5E_BW))):
        for name, (flops, gbytes, batch) in MODELS.items():
            n = 4
            comp, ring, opt = breakdown(flops, gbytes, batch, n, peak, bw)
            total_ring = comp + ring
            total_opt = comp + opt
            emit(f"fig7b.{hw}.{name}", 0.0,
                 f"compute_ms={comp * 1e3:.2f} ring_comm_ms={ring * 1e3:.2f} "
                 f"optinc_comm_ms={opt * 1e3:.2f} "
                 f"latency_reduction={1 - total_opt / total_ring:.3f}")


if __name__ == "__main__":
    main()
