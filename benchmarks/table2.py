"""Paper Table II: matrix-approximation layer sweep for scenario 4 —
area ratio per selected-layer set + the paper's measured error model
(reused for error injection in fig7a).

The ``mesh_check`` row programs a representative approximated layer
(Sigma_a U_a blocks -> Givens phases) and verifies it through the FAST
jax mesh emulator (repro.photonics.mesh) instead of the numpy loop:
programmed-MZI count vs the area model's budget, and emulator output vs
the projected weight matrix."""
from __future__ import annotations

import numpy as np

from repro.photonics import approx, area, error_model, mesh, mzi

from .common import emit

ST4 = [4, 64, 128, 256, 512, 256, 128, 64, 8]
PAPER_ROWS = [((4, 5, 6), 0.493), ((4, 5, 6, 7), 0.479),
              ((4, 5, 6, 7, 8), 0.474), ((3, 4, 5, 6), 0.437),
              ((3, 4, 5, 6, 7), 0.422)]


def mesh_check(m: int = 128, n: int = 64):
    """Program one approximated m x n layer and run the jax emulator."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    w = rng.normal(size=(m, n))
    s = approx.block_size(m, n)
    blocks, wa_rows = [], []
    for ws in w.reshape(m // s, s, n):
        d, ua = approx.approx_block_factors(ws)
        blocks.append({"d": d, "u": mzi.givens_decompose(ua)})
        wa_rows.append(d[:, None] * ua)
    wa = np.concatenate(wa_rows, axis=0)       # the Sigma_a U_a projection
    prog = mesh.compile_layer({"kind": "approx", "blocks": blocks,
                               "shape": (m, n), "b": np.zeros(m)})
    x = rng.normal(size=(64, n)).astype(np.float32)
    got = np.asarray(prog.apply(jnp.asarray(x)))
    err = float(np.abs(got - x @ wa.T).max())
    budget = area.mzi_count_approx(m, n)
    assert prog.num_mzis <= budget, (prog.num_mzis, budget)
    assert err < 1e-3, err
    emit("table2.mesh_check", 0.0,
         f"layer={m}x{n} mzis_model={budget} mzis_programmed={prog.num_mzis} "
         f"emulator_max_err={err:.2e}")


def main(full: bool = False):
    for layers, paper in PAPER_ROWS:
        ratio = area.area_ratio(ST4, set(layers))
        spec = error_model.TABLE_II[layers]
        errs = ",".join(f"{v}:{r:g}" for v, r in zip(spec.values, spec.ratios))
        emit(f"table2.layers_{'_'.join(map(str, layers))}", 0.0,
             f"area_ratio={ratio:.3f} paper={paper} "
             f"onn_acc={spec.accuracy} errors=[{errs}]")
    mesh_check()


if __name__ == "__main__":
    main()
