"""Paper Table II: matrix-approximation layer sweep for scenario 4 —
area ratio per selected-layer set + the paper's measured error model
(reused for error injection in fig7a)."""
from __future__ import annotations

from repro.core import area, error_model

from .common import emit

ST4 = [4, 64, 128, 256, 512, 256, 128, 64, 8]
PAPER_ROWS = [((4, 5, 6), 0.493), ((4, 5, 6, 7), 0.479),
              ((4, 5, 6, 7, 8), 0.474), ((3, 4, 5, 6), 0.437),
              ((3, 4, 5, 6, 7), 0.422)]


def main(full: bool = False):
    for layers, paper in PAPER_ROWS:
        ratio = area.area_ratio(ST4, set(layers))
        spec = error_model.TABLE_II[layers]
        errs = ",".join(f"{v}:{r:g}" for v, r in zip(spec.values, spec.ratios))
        emit(f"table2.layers_{'_'.join(map(str, layers))}", 0.0,
             f"area_ratio={ratio:.3f} paper={paper} "
             f"onn_acc={spec.accuracy} errors=[{errs}]")


if __name__ == "__main__":
    main()
