"""Streaming-engine benchmark: backward/comm overlap on vs off.

Runs the acceptance scenario end-to-end twice in one subprocess — the
paper-LLaMA smoke config, ``--sync cascade`` on a (pod=2, data=2) mesh,
3 steps — with the barrier engine and with ``--overlap``, and emits one
row per variant:

  us_per_call       measured steady-state step wall time (min over the
                    post-compile steps; CPU CI has no optical fabric, so
                    wall time mostly shows the two dispatch strategies
                    compile/run comparably)
  time_on_wire_us   the analytic fabric-occupancy model for the SAME spec
                    (backend.time_on_wire via api.build.modeled_time_on_wire)
  wire_ratio        on/off modeled wire time — the perf gate holds this
                    <= 1.0 (streaming must never cost wire time)
  losses_match      1 iff the two runs' per-step losses are identical —
                    the gate holds the streaming engine to bit-identical
                    numerics, not just similar convergence

Rows mirror to results/bench/overlap.json; the committed
results/bench/overlap_baseline.json is the regression reference
(scripts/check_perf_regression.py, section ``overlap``).

    PYTHONPATH=src python -m benchmarks.overlap [--smoke] [--full]
"""
from __future__ import annotations

import argparse
import json
import sys

from .common import emit, flush_json, run_subprocess

sys.path.insert(0, "src")

BUCKET_MB = 4        # the engine default: 41 buckets for the 43M model

RUN = """
import json, io, contextlib
import repro.launch.train as T
out = {{}}
for label, extra in (("off", []), ("on", ["--overlap"])):
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        T.main(["--arch", "paper_llama", "--smoke-config", "--sync",
                "cascade", "--mesh", "2x1", "--steps", "{steps}",
                "--global-batch", "8", "--seq-len", "128",
                "--bucket-mb", "{bucket_mb}"] + extra)
    recs = [json.loads(l) for l in buf.getvalue().splitlines()
            if l.startswith("{{")]
    out[label] = {{"losses": [r["loss"] for r in recs],
                   "step_s": [r["time_s"] for r in recs]}}
print(json.dumps(out))
"""


def modeled_wire_us(overlap: bool, bucket_mb: float) -> float:
    from repro.api import MeshSpec, RunSpec, SyncConfig, build
    spec = RunSpec(arch="paper_llama", smoke=True,
                   mesh=MeshSpec(pods=2, dp=2, tp=1),
                   sync=SyncConfig(mode="cascade", bits=8,
                                   bucket_bytes=int(bucket_mb * 2 ** 20)))
    return build.modeled_time_on_wire(spec, overlap=overlap) * 1e6


def main(full: bool = False, smoke: bool = False):
    try:
        _run(full=full, smoke=smoke)
    finally:
        flush_json("overlap")


def _run(full: bool, smoke: bool):
    steps = 5 if full else 3
    out = json.loads(run_subprocess(
        RUN.format(steps=steps, bucket_mb=BUCKET_MB),
        devices=4, timeout=3000).strip().splitlines()[-1])
    match = int(out["off"]["losses"] == out["on"]["losses"]
                and len(out["off"]["losses"]) == steps)
    t_off = modeled_wire_us(False, BUCKET_MB)
    t_on = modeled_wire_us(True, BUCKET_MB)
    # step 0 pays the jit compile; steady state = min of the rest
    wall = {k: min(v["step_s"][1:] or v["step_s"]) * 1e6
            for k, v in out.items()}
    emit("overlap.cascade.off", wall["off"],
         f"time_on_wire_us={t_off:.1f} steps={steps}")
    emit("overlap.cascade.on", wall["on"],
         f"time_on_wire_us={t_on:.1f} wire_ratio={t_on / t_off:.3f} "
         f"losses_match={match} steps={steps}")
    if not match:
        raise RuntimeError(
            f"overlap-on losses diverged from overlap-off: "
            f"{out['on']['losses']} vs {out['off']['losses']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI-invocation symmetry (the run is "
                         "already the smoke scenario)")
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke)
