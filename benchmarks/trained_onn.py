"""Trained-ONN fidelity regression at B=8 (nightly CI; ROADMAP item).

The tier-1 suite only ever exercises the bits<=2 built-in exact-identity
ONN; this harness closes the gap for a *trained* wide-bit ONN:

1. Load ``results/scenario1*_params.pkl`` (produced by
   ``python examples/quickstart.py --scenario1`` — the nightly job's
   first step) and measure the paper's 'ONN Accuracy' — the fraction of
   the FULL scenario-1 input grid whose reconstructed gradient is exact
   — through both the dense forward pass and the phase-programmed mesh
   emulator.  The accuracy must clear ``--min-accuracy``; the default
   floor is the worst Table-II row (0.9998891, scenario 4's (3,4,5,6)
   layer set) — the paper's own bound on how inexact a usable in-network
   ONN gets.
2. Run a short ``--fidelity onn --bits 8`` training smoke on a 4-host
   device mesh through the SAME ``repro.launch.train`` entry point CI
   and users call, proving the trained params resolve (runtime 'results'
   source), jit-compile inside ``sync_gradients``, and train end-to-end.

    PYTHONPATH=src python -m benchmarks.trained_onn \
        [--min-accuracy 0.9998891] [--steps 3] [--skip-e2e]
"""
from __future__ import annotations

import argparse
import json

from .common import emit, flush_json, load_scenario1, run_subprocess


def _table_ii_floor() -> float:
    """The worst accuracy the paper still calls a usable in-network ONN
    (Table II; currently the (3,4,5,6) layer set) — derived from the one
    source of truth in repro.photonics.error_model."""
    from repro.photonics import error_model
    return min(spec.accuracy for spec in error_model.TABLE_II.values())

E2E_RUN = """
import json, io, contextlib
import repro.launch.train as T
buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    T.main(["--arch", "minitron_4b", "--smoke-config", "--sync", "optinc",
            "--bits", "8", "--fidelity", "onn", "--mesh", "4x1",
            "--steps", "{steps}", "--global-batch", "8", "--seq-len", "64",
            "--lr", "1e-3", "--bucket-mb", "0.5"])
recs = [json.loads(l) for l in buf.getvalue().splitlines()
        if l.startswith("{{")]
print(json.dumps({{"steps": len(recs), "first": recs[0]["loss"],
                   "last": recs[-1]["loss"]}}))
"""


def measure_accuracy(min_accuracy: float) -> float:
    """Paper 'ONN Accuracy' of the persisted scenario-1 params on the full
    grid, via the dense path AND a mesh-emulator spot check."""
    import jax.numpy as jnp
    import numpy as np

    from repro.photonics import ONNModule, dataset, training

    blob = load_scenario1()
    if blob is None:
        raise RuntimeError(
            "no results/scenario1*_params.pkl — run "
            "`python examples/quickstart.py --scenario1` first (the nightly "
            "workflow's produce-params job)")
    cfg = blob["cfg"]
    if cfg.bits != 8:
        raise RuntimeError(f"scenario-1 pickle has bits={cfg.bits}, "
                           f"expected the B=8 scenario")
    a, t = dataset.full_dataset(cfg)
    acc = training.accuracy(blob["params"], a, t, cfg)
    emit("trained_onn.accuracy.b8.dense", 0.0,
         f"acc={acc:.7f} floor={min_accuracy:g} samples={len(a)} "
         f"structure={tuple(cfg.structure)}")
    if acc < min_accuracy:
        # fail fast: the primary regression signal, checked before the
        # (slower) mesh/pallas spot checks
        raise RuntimeError(
            f"trained B=8 ONN accuracy {acc:.7f} fell below the Table-II "
            f"floor {min_accuracy:g} — scenario-1 training regressed")

    # the programmed meshes must reproduce the dense decisions on a slice
    module = ONNModule.from_params(cfg, blob["params"])
    sl = jnp.asarray(a[:2048])
    dense_sym = np.asarray(module.symbols(sl, fidelity="onn"))
    mesh_sym = np.asarray(module.symbols(sl, fidelity="mesh"))
    pallas_sym = np.asarray(module.symbols(sl, fidelity="mesh",
                                           mesh_backend="pallas"))
    mesh_match = float(np.mean(np.all(mesh_sym == dense_sym, -1)))
    pallas_match = float(np.mean(np.all(pallas_sym == mesh_sym, -1)))
    emit("trained_onn.mesh_vs_dense.b8", 0.0,
         f"symbol_match={mesh_match:.5f} pallas_vs_xla={pallas_match:.5f} "
         f"slice=2048")
    if mesh_match < min_accuracy:
        # the programmed meshes get the same error budget as the ONN
        # itself (readouts may flip only near decision boundaries)
        raise RuntimeError(
            f"mesh-emulator readout matched the dense ONN on only "
            f"{mesh_match:.5f} of the slice (floor {min_accuracy:g}) — "
            f"the Givens programming / emulator regressed")
    if pallas_match < min_accuracy:
        # interpret mode (CPU CI) is bit-exact in practice; compiled on
        # TPU the MXU one-hot path may round differently at a PAM4
        # decision boundary, so the executors share the Table-II budget
        # rather than demanding bit-identical decisions
        raise RuntimeError(
            f"pallas mesh backend changed {1 - pallas_match:.2%} of readout "
            f"decisions vs the xla scan (floor {min_accuracy:g})")
    return acc


def e2e_training_smoke(steps: int) -> dict:
    """--fidelity onn --bits 8 through the real train.py on 4 devices."""
    out = run_subprocess(E2E_RUN.format(steps=steps), devices=4)
    rec = json.loads(out.strip().splitlines()[-1])
    emit("trained_onn.e2e.b8.fidelity_onn", 0.0,
         f"steps={rec['steps']} first={rec['first']} last={rec['last']}")
    if rec["steps"] < steps:
        raise RuntimeError(f"e2e run logged {rec['steps']} steps, "
                           f"expected {steps}")
    return rec


def main(full: bool = False, smoke: bool = False, strict: bool = False,
         min_accuracy: float | None = None, steps: int = 3,
         skip_e2e: bool = False) -> None:
    if min_accuracy is None:
        min_accuracy = _table_ii_floor()
    try:
        if not strict and load_scenario1() is None:
            # benchmarks.run sweep: the pickle is a nightly artifact, not a
            # repo file — absent params are a skip, not a failure
            emit("trained_onn.skipped", 0.0,
                 "no results/scenario1*_params.pkl (run quickstart "
                 "--scenario1); section skipped")
            return
        measure_accuracy(min_accuracy)
        if not skip_e2e:
            e2e_training_smoke(steps)
    finally:
        flush_json("trained_onn")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--min-accuracy", type=float, default=None,
                    help="accuracy floor (default: worst Table-II row)")
    ap.add_argument("--steps", type=int, default=3,
                    help="e2e training-smoke step count")
    ap.add_argument("--skip-e2e", action="store_true",
                    help="only the accuracy regression (no 4-device run)")
    args = ap.parse_args()
    try:
        main(strict=True, min_accuracy=args.min_accuracy, steps=args.steps,
             skip_e2e=args.skip_e2e)
    except RuntimeError as e:
        raise SystemExit(str(e))
