"""Paper Table I: ONN structures, area ratios, trained accuracy.

Area ratios are computed exactly from the MZI cost model for all four
scenarios. ONN accuracy: scenario 1 is fully trained in this container
(results/scenario1_params.pkl, produced by examples/quickstart.py or the
background training run); scenarios 2-4 report the area model plus a
subsampled-training accuracy when --full is given (their full grids are up
to 13.8M samples — paper trains them on A100s).
"""
from __future__ import annotations

import numpy as np

from repro.photonics import area, dataset, training
from repro.photonics import ONNConfig

from .common import emit, load_scenario1

SCENARIOS = [
    # bits, servers, structure, approx layers, paper area ratio
    (8, 4, (4, 64, 128, 256, 128, 64, 4), tuple(range(1, 7)), 0.393),
    (8, 8, (4, 64, 128, 256, 512, 256, 128, 64, 4), tuple(range(2, 8)), 0.409),
    (8, 16, (4, 64, 128, 256, 512, 1024, 512, 256, 128, 64, 4),
     tuple(range(2, 10)), 0.404),
    (16, 4, (4, 64, 128, 256, 512, 256, 128, 64, 8), (4, 5, 6), 0.493),
]


def main(full: bool = False):
    blob = load_scenario1()
    for i, (bits, n, structure, approx_layers, paper) in enumerate(SCENARIOS, 1):
        cfg = ONNConfig(structure=structure, approx_layers=approx_layers,
                        bits=bits, n_servers=n, k_inputs=4)
        ratio = area.area_ratio(list(structure), set(approx_layers))
        acc = ""
        if i == 1 and blob is not None:
            a, t = dataset.full_dataset(blob["cfg"])
            acc = training.accuracy(blob["params"], a, t, blob["cfg"])
            acc = f"acc={acc:.6f}"
        elif full:
            rng = np.random.default_rng(0)
            a, t = dataset.sampled_dataset(cfg, rng, 100_000)
            tc = training.TrainConfig(epochs=600, e1=500, lr=8e-3,
                                      batch_size=8192, proj_every=100)
            params, _ = training.train(cfg, tc, a, t, eval_every=100)
            acc = f"acc={training.accuracy(params, a, t, cfg):.6f}(subsampled)"
        emit(f"table1.scenario{i}.B{bits}.N{n}", 0.0,
             f"area_ratio={ratio:.3f} paper={paper} "
             f"dataset={dataset.dataset_size(cfg)} {acc}")


if __name__ == "__main__":
    main()
