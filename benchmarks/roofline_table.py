"""Aggregate results/dryrun/*.json into the §Roofline table."""
from __future__ import annotations

import json

from repro import configs
from repro.launch.roofline import PEAK_FLOPS

from .common import DRYRUN, emit


def model_flops(arch: str, tokens: int) -> float:
    cfg = configs.get(arch)
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    return 6.0 * n * tokens


def main(full: bool = False):
    rows = sorted(DRYRUN.glob("*.json"))
    for p in rows:
        rec = json.loads(p.read_text())
        tag = p.stem
        if rec.get("skipped"):
            emit(f"roofline.{tag}", 0.0, f"SKIP({rec['skipped']})")
            continue
        r = rec["roofline"]
        shape = configs.SHAPES[rec["shape"]]
        if rec["kind"] == "train":
            tokens = shape["seq_len"] * shape["global_batch"]
            mf = model_flops(rec["arch"], tokens) / rec["chips"]  # 6ND = fwd+bwd
        elif rec["kind"] == "prefill":
            tokens = shape["seq_len"] * shape["global_batch"]
            mf = model_flops(rec["arch"], tokens) / 3 / rec["chips"]  # 2ND fwd
        else:
            tokens = shape["global_batch"]
            mf = model_flops(rec["arch"], tokens) / 3 / rec["chips"]
        # XLA cost_analysis counts loop/scan bodies ONCE, so HLO flops is a
        # lower bound for scanned programs; the analytic 6ND/2ND term is the
        # reliable compute floor. Report both and bound with their max.
        compute_eff = max(r["compute_s"], mf / PEAK_FLOPS)
        useful = min(mf / max(rec["flops_per_device"], 1.0), 1.0)
        dom = r["dominant"]
        if compute_eff >= max(r["memory_s"], r["collective_s"]):
            dom = "compute"
        bound = max(compute_eff, r["memory_s"], r["collective_s"])
        frac = (mf / PEAK_FLOPS) / bound if bound else 0.0
        emit(f"roofline.{tag}", 0.0,
             f"compute_s={compute_eff:.4f} memory_s={r['memory_s']:.4f} "
             f"collective_s={r['collective_s']:.4f} dominant={dom} "
             f"peak_GiB={rec['memory']['peak_bytes'] / 2**30:.2f} "
             f"useful_flops_ratio={useful:.3f} roofline_frac={frac:.3f}")


if __name__ == "__main__":
    main()
