"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import pathlib
import pickle
import subprocess
import sys
import time

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"
DRYRUN = RESULTS / "dryrun"     # shared with launch/dryrun.py --out and
                                # scripts/fix_dryrun_stats.py --out
BENCH_JSON = RESULTS / "bench"  # per-section JSON row dumps (CI artifacts)

_ROWS: list = []                # rows emitted since the last flush/reset


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                  "derived": derived})


def reset_rows():
    """Drop buffered rows (benchmarks.run calls this between sections so a
    failed section cannot leak rows into the next section's JSON)."""
    _ROWS.clear()


def flush_json(section: str) -> pathlib.Path:
    """Write (and clear) the rows emitted since the last flush to
    ``results/bench/<section>.json`` — the machine-readable mirror of the
    CSV stdout, uploaded as a CI artifact per commit."""
    BENCH_JSON.mkdir(parents=True, exist_ok=True)
    path = BENCH_JSON / f"{section}.json"
    path.write_text(json.dumps(_ROWS, indent=1) + "\n")
    _ROWS.clear()
    return path


def timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
    return out, (time.time() - t0) / repeats * 1e6


def run_subprocess(code: str, devices: int = 0, timeout: int = 2400) -> str:
    import os
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env,
                       cwd=str(RESULTS.parent))
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return r.stdout


def load_scenario1():
    # prefer the constraint-exact cayley-mode run (100% accuracy)
    for name in ("scenario1_cayley_params.pkl", "scenario1_params.pkl"):
        p = RESULTS / name
        if p.exists():
            with open(p, "rb") as f:
                return pickle.load(f)
    return None
