"""Paper Fig. 6: communication data (normalized by gradient bytes) for
ring all-reduce vs OptINC at N = 4, 8, 16 servers.

Two measurements:
  analytic — the paper's model: ring moves 2(N-1)/N units per direction
             (reduce-scatter + all-gather); OptINC moves exactly 1 unit
             (one send, one receive through the optical network).
  measured — the per-device wire bytes parsed from the COMPILED HLO of the
             paper-LLaMA train step on an N-device mesh, for sync modes
             ring / optinc / psum (this framework's programs, not formulas).
"""
from __future__ import annotations

import json

from .common import emit, run_subprocess

MEASURE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import json
import jax, jax.numpy as jnp
from repro import configs
from repro.core.collective import SyncConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step
from repro.launch.roofline import parse_collectives
from repro.launch.dryrun import batch_sds, opt_sds
from repro.models import lm
from repro.optim import AdamWConfig

cfg = configs.get("paper_llama")
mesh = make_mesh(({n}, 1), ("data", "model"))
out = {{}}
p_sds = None
for mode in ("ring", "optinc", "psum"):
    sync = SyncConfig(mode=mode, axes=("data",), bits=8, block=2048)
    step, _, _ = make_train_step(cfg, mesh, sync, AdamWConfig())
    from repro.launch.steps import make_ctx
    ctx = make_ctx(mesh)
    p_sds = lm.param_shape_dtype(cfg, ctx)
    args = (p_sds, opt_sds(p_sds), batch_sds(cfg, 512, {n}),
            jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    with jax.set_mesh(mesh):
        compiled = jax.jit(step).lower(*args).compile()
    colls = parse_collectives(compiled.as_text())
    total = sum(v["bytes"] for v in colls.values())
    out[mode] = {{"colls": colls, "result_bytes": total}}
nparams = sum(s.size for s in jax.tree.leaves(p_sds))
out["grad_bytes_bf16"] = nparams * 2
print(json.dumps(out))
"""


def main(full: bool = False):
    for n in (4, 8, 16):
        ring = 2 * (n - 1) / n
        emit(f"fig6.analytic.N{n}", 0.0,
             f"ring={ring:.3f} optinc=1.0 overhead_eliminated={(n - 2) / n:.3f}")
    for n in ((4, 8, 16) if full else (8,)):
        stdout = run_subprocess(MEASURE.format(n=n), timeout=2400)
        rec = json.loads(stdout.strip().splitlines()[-1])
        gb = rec["grad_bytes_bf16"]
        for mode in ("ring", "optinc", "psum"):
            rb = rec[mode]["result_bytes"]
            emit(f"fig6.measured_hlo.N{n}.{mode}", 0.0,
                 f"collective_result_bytes={rb} norm_vs_bf16_grads={rb / gb:.3f}")


if __name__ == "__main__":
    main()
