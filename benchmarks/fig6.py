"""Paper Fig. 6: communication data (normalized by gradient bytes) for
ring all-reduce vs OptINC (and the III-C cascade) at N = 4, 8, 16 servers.

Two measurements:
  analytic — per-backend wire bytes from the collective engine's own
             accounting hooks (backend.bytes_on_wire, EXPERIMENTS.md
             §Fig6), normalized by the bf16 gradient bytes: ring moves
             2(N-1)/N units, OptINC ~B/16 units (one quantized send),
             cascade adds the amortized level-1 -> level-2 carry link.
  measured — the per-device wire bytes parsed from the COMPILED HLO of the
             paper-LLaMA train step on an N-device mesh, for every
             registered sync mode (this framework's programs, not
             formulas). cascade runs on a (pod=2, data=N/2) mesh.

Next to each bytes column sits the TIME column (backend.time_on_wire,
EXPERIMENTS.md §Overlap): per-device wire/fabric-occupancy seconds —
line-rate transfer plus per-bucket MZI reconfiguration — with an
overlap=off and an overlap=on row each, so the figure shows what the
streaming engine buys on top of the byte reduction.  The measured rows
feed the REAL paper-LLaMA gradient size (from the compiled HLO run) into
the same model.  All rows mirror to ``results/bench/fig6.json``.
"""
from __future__ import annotations

import json
import sys

from .common import emit, flush_json, run_subprocess

sys.path.insert(0, "src")

from repro.collectives import get_backend  # noqa: E402

MODES = ("ring", "optinc", "psum", "cascade")

MEASURE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import json
import jax, jax.numpy as jnp
from repro.api import MeshSpec, RunSpec, SyncConfig, build
from repro.api.shapes import batch_sds, opt_sds
from repro.collectives import expected_buckets
from repro.launch.roofline import parse_collectives
from repro.models import lm

out = {{}}
p_sds = None
for mode in {modes}:
    mesh_spec = (MeshSpec(pods=2, dp={n} // 2, tp=1) if mode == "cascade"
                 else MeshSpec(dp={n}, tp=1))
    spec = RunSpec(arch="paper_llama", mesh=mesh_spec,
                   sync=SyncConfig(mode=mode, bits=8, block=2048,
                                   bucket_bytes={bucket_bytes}))
    cfg = spec.model_config()
    mesh = spec.mesh.build()
    step, _, _ = build.build_train_step(spec, cfg, mesh)
    p_sds = lm.param_shape_dtype(cfg, spec.mesh.ctx())
    args = (p_sds, opt_sds(p_sds), {{}}, batch_sds(cfg, 512, {n}),
            jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    with jax.set_mesh(mesh):
        compiled = jax.jit(step).lower(*args).compile()
    colls = parse_collectives(compiled.as_text())
    total = sum(v["bytes"] for v in colls.values())
    out[mode] = {{"colls": colls, "result_bytes": total}}
nparams = sum(s.size for s in jax.tree.leaves(p_sds))
out["grad_bytes_bf16"] = nparams * 2
out["bucket_budget"] = expected_buckets(nparams * 4, {bucket_bytes})
print(json.dumps(out))
"""

BUCKET_BYTES = 4 * 2 ** 20


def analytic(n: int, bits: int = 8) -> dict:
    """Normalized per-backend wire units (vs bf16 gradient bytes) from the
    engine's bytes_on_wire hooks.  The cascade row uses the same
    (pod=2, data=n/2) split as the measured mesh so the two rows describe
    one topology."""
    nbytes = 2.0 * 1_000_000  # 1M bf16 gradient elements
    out = {m: get_backend(m).bytes_on_wire(nbytes, n, bits) / nbytes
           for m in MODES if m != "cascade"}
    out["cascade"] = get_backend("cascade").bytes_on_wire(
        nbytes, n, bits, n1=max(n // 2, 1)) / nbytes
    return out


def wire_time(nbytes: float, n: int, mode: str, overlap: bool,
              bits: int = 8, bucket_bytes: int = BUCKET_BYTES) -> float:
    """backend.time_on_wire with fig6's (pod=2, data=n/2) cascade split."""
    kw = {"n1": max(n // 2, 1)} if mode == "cascade" else {}
    return get_backend(mode).time_on_wire(
        nbytes, n, bits, overlap=overlap, bucket_bytes=bucket_bytes, **kw)


def emit_time_rows(prefix: str, nbytes: float, n: int):
    """One time-on-wire row per (mode, overlap) next to the bytes rows."""
    for mode in MODES:
        t_off = wire_time(nbytes, n, mode, overlap=False)
        t_on = wire_time(nbytes, n, mode, overlap=True)
        emit(f"{prefix}.N{n}.{mode}.overlap_off", 0.0,
             f"time_on_wire_us={t_off * 1e6:.1f}")
        emit(f"{prefix}.N{n}.{mode}.overlap_on", 0.0,
             f"time_on_wire_us={t_on * 1e6:.1f} "
             f"wire_ratio={t_on / t_off:.3f}")


def main(full: bool = False):
    try:
        _run(full)
    finally:
        flush_json("fig6")


def _run(full: bool):
    for n in (4, 8, 16):
        units = analytic(n)
        ring = units["ring"]
        emit(f"fig6.analytic.N{n}", 0.0,
             " ".join(f"{m}={units[m]:.3f}" for m in MODES)
             + f" overhead_vs_optinc={(ring - units['optinc']) / ring:.3f}")
        emit_time_rows("fig6.analytic_time", 2.0 * 1_000_000, n)
    for n in ((4, 8, 16) if full else (8,)):
        stdout = run_subprocess(
            MEASURE.format(n=n, modes=repr(MODES),
                           bucket_bytes=BUCKET_BYTES), timeout=2400)
        rec = json.loads(stdout.strip().splitlines()[-1])
        gb = rec["grad_bytes_bf16"]
        for mode in MODES:
            rb = rec[mode]["result_bytes"]
            n_rs = sum(v["count"] for k, v in rec[mode]["colls"].items()
                       if k.startswith("reduce-scatter"))
            emit(f"fig6.measured_hlo.N{n}.{mode}", 0.0,
                 f"collective_result_bytes={rb} "
                 f"norm_vs_bf16_grads={rb / gb:.3f} "
                 f"reduce_scatter_launches={n_rs} "
                 f"bucket_budget={rec['bucket_budget']}")
        # time column for the REAL paper-LLaMA gradient size (same model,
        # measured payload): one off/on row pair per mode
        emit_time_rows("fig6.measured_time", float(gb), n)


if __name__ == "__main__":
    main()
