"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6,...]

Prints ``name,us_per_call,derived`` CSV lines. --full enables the long
variants (subsampled scenario 2-4 training, all fig6 mesh sizes, longer
fig7a runs).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (common, elastic, fig6, fig7a, fig7b, mesh_emulation, overlap,
               roofline_table, serve_throughput, table1, table2, trained_onn)

SECTIONS = {
    "table1": table1.main,
    "table2": table2.main,
    "fig6": fig6.main,
    "fig7a": fig7a.main,
    "fig7b": fig7b.main,
    "mesh_emulation": mesh_emulation.main,
    "trained_onn": trained_onn.main,
    "roofline": roofline_table.main,
    "serve_throughput": serve_throughput.main,
    "overlap": overlap.main,
    "elastic": elastic.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SECTIONS)
    failures = 0
    for name, fn in SECTIONS.items():
        if name not in only:
            continue
        print(f"# --- {name} ---")
        common.reset_rows()  # a failed section must not leak rows forward
        try:
            fn(full=args.full)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,ERROR")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
