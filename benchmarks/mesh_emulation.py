"""Numpy-vs-JAX MZI mesh emulation throughput (EXPERIMENTS.md §Mesh).

The numpy oracle (repro.photonics.mzi) rebuilds an orthogonal from its
phase program one Givens matrix at a time — the cost every
``apply_hardware`` call used to pay.  The jax emulator
(repro.photonics.mesh) compiles the program once into stacked rotation
layers and applies them with lax.scan + gather/scatter.  This harness
measures both on the same programs and asserts the emulator's >= 10x
advantage (the acceptance bar of the photonics refactor; in practice it
is orders of magnitude).

    PYTHONPATH=src python -m benchmarks.mesh_emulation [--smoke] [--full]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.photonics import mesh, mzi, onn
from repro.photonics.onn import ONNConfig

from .common import emit, timed

TINY = ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                 bits=4, n_servers=2, k_inputs=2)

MIN_SPEEDUP = 10.0


def _block(x):
    jax.tree.map(lambda a: a.block_until_ready(), x)
    return x


def bench_orthogonal(m: int, batch: int) -> list:
    """One m-port mesh: numpy reconstruct+matmul vs compiled scan apply.
    Returns the [reconstruct, batched-apply] speedups.

    The numpy loop is O(K m^2) = O(m^4) per rebuild and batch-independent;
    the emulator is O(L m) = O(m^2) per applied vector — its advantage
    grows with the port count and is amortized-rebuild per call."""
    rng = np.random.default_rng(m)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    prog = mzi.givens_decompose(q)
    emu = mesh.MZIMesh.compile(prog)
    x = rng.normal(size=(batch, m)).astype(np.float32)
    xj = jnp.asarray(x)

    _, np_rec_us = timed(mzi.reconstruct, prog, repeats=1)
    jit_mat = jax.jit(emu.matrix)
    _, jx_rec_us = timed(lambda: _block(jit_mat()))
    rec = np_rec_us / jx_rec_us
    emit(f"mesh_emulation.reconstruct.m{m}", jx_rec_us,
         f"numpy_us={np_rec_us:.0f} jax_us={jx_rec_us:.0f} "
         f"speedup={rec:.1f}")

    # application semantics of the numpy oracle: rebuild + matmul per call
    _, np_app_us = timed(lambda: x @ mzi.reconstruct(prog).T, repeats=1)
    jit_apply = jax.jit(emu.apply)
    _, jx_app_us = timed(lambda: _block(jit_apply(xj)))
    app = np_app_us / jx_app_us
    emit(f"mesh_emulation.apply.m{m}.b{batch}", jx_app_us,
         f"numpy_us={np_app_us:.0f} jax_us={jx_app_us:.0f} "
         f"speedup={app:.1f}")
    return [rec, app]


def bench_onn_forward(batch: int) -> float:
    """Full programmed-ONN forward pass: numpy apply_hardware oracle vs
    the compiled emulator.  Returns the speedup."""
    params = onn.project_approx(onn.init_params(TINY, jax.random.PRNGKey(0)),
                                TINY)
    hw = onn.map_to_hardware(params, TINY)
    progs = mesh.compile_hardware(hw)
    a = np.random.default_rng(0).uniform(
        0, TINY.in_scale, size=(batch, 2)).astype(np.float32)
    aj = jnp.asarray(a)

    _, np_us = timed(onn.apply_hardware, hw, a, TINY, repeats=1)
    fwd = jax.jit(lambda x: mesh.apply_hardware(progs, x, TINY))
    _, jx_us = timed(lambda: _block(fwd(aj)))
    speedup = np_us / jx_us
    emit(f"mesh_emulation.onn_forward.tiny.b{batch}", jx_us,
         f"numpy_us={np_us:.0f} jax_us={jx_us:.0f} speedup={speedup:.1f}")
    return speedup


def main(full: bool = False, smoke: bool = False) -> None:
    sizes = [(128, 1024)] if smoke else [(64, 256), (128, 2048)]
    if full:
        sizes.append((192, 2048))
    speedups = []
    for m, b in sizes:
        speedups.extend(bench_orthogonal(m, b))
    speedups.append(bench_onn_forward(256))
    worst = min(speedups)
    emit("mesh_emulation.min_speedup", 0.0,
         f"worst_speedup={worst:.1f} required={MIN_SPEEDUP:g}")
    if worst < MIN_SPEEDUP:
        # RuntimeError (not SystemExit) so benchmarks.run's harness can
        # record the section failure and keep sweeping
        raise RuntimeError(
            f"mesh emulator speedup {worst:.1f}x below the {MIN_SPEEDUP:g}x "
            f"acceptance bar")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest sizes only (CI)")
    ap.add_argument("--full", action="store_true",
                    help="add the 192-port mesh")
    args = ap.parse_args()
    try:
        main(full=args.full, smoke=args.smoke)
    except RuntimeError as e:
        raise SystemExit(str(e))
