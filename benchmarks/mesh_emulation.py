"""Numpy-vs-XLA-vs-Pallas MZI mesh emulation throughput (EXPERIMENTS.md §Mesh).

Three executors of the same compiled phase program:

* **numpy oracle** (repro.photonics.mzi): rebuilds the orthogonal one
  Givens matrix at a time — the cost every ``apply_hardware`` call used
  to pay.  Unjittable; kept as the correctness oracle.
* **xla** (repro.photonics.mesh): stacked rotation layers under one
  ``lax.scan`` — one gather+FMA (and one HBM round-trip of the batch)
  per layer.
* **pallas** (repro.kernels.mesh_scan): the whole L-layer cascade fused
  in VMEM — one kernel launch per batch tile, one HBM read/write total
  (``PhotonicsConfig.mesh_backend='pallas'``).

The harness measures all three on identical programs, asserts the XLA
emulator's >= 10x bar over numpy (the photonics-refactor acceptance bar)
and the pallas path's parity with XLA.  The pallas >= 10x bar is only
enforced when the kernel actually compiles (TPU); off-TPU it runs in
interpret mode, whose rows are informational (the interpreter evaluates
the kernel with jax ops and is not a speed claim).

The block-batched rows (``mesh_emulation.blocked.*``) time
``ApproxLayerProgram``-style stacked programs: the vmapped xla scan
against ONE ``mesh_scan_blocks`` launch with the block axis folded into
the kernel grid.  ``--blk-b-sweep`` is the measured ``blk_b`` selection
mode: it times the kernel at each candidate batch tile and reports the
fastest (set it via ``--blk-b`` / ``PhotonicsConfig.blk_b``).

    PYTHONPATH=src python -m benchmarks.mesh_emulation \
        [--smoke] [--full] [--parity] [--blk-b-sweep]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.photonics import mesh, mzi, onn
from repro.photonics.onn import ONNConfig

from .common import emit, flush_json, timed

TINY = ONNConfig(structure=(2, 64, 128, 64, 2), approx_layers=(2, 3),
                 bits=4, n_servers=2, k_inputs=2)

MIN_SPEEDUP = 10.0       # xla-vs-numpy bar (always enforced)
PALLAS_MIN_SPEEDUP = 10.0  # pallas-vs-numpy bar (enforced on TPU only)
PARITY_ATOL = 1e-4       # pallas-vs-xla f32 agreement (1e-6 under x64,
                         # tests/test_mesh_kernel.py)


def _block(x):
    jax.tree.map(lambda a: a.block_until_ready(), x)
    return x


def _pallas_enforced() -> bool:
    """The pallas speedup bar only binds where the kernel compiles."""
    return jax.default_backend() == "tpu"


def bench_orthogonal(m: int, batch: int) -> list:
    """One m-port mesh: numpy reconstruct+matmul vs compiled scan apply vs
    the fused pallas kernel.  Returns the enforced speedups.

    The numpy loop is O(K m^2) = O(m^4) per rebuild and batch-independent;
    the layered emulators are O(L m) per applied vector — their advantage
    grows with the port count and is amortized-rebuild per call."""
    rng = np.random.default_rng(m)
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    prog = mzi.givens_decompose(q)
    emu = mesh.MZIMesh.compile(prog)
    x = rng.normal(size=(batch, m)).astype(np.float32)
    xj = jnp.asarray(x)

    _, np_rec_us = timed(mzi.reconstruct, prog, repeats=1)
    jit_mat = jax.jit(emu.matrix)
    _, jx_rec_us = timed(lambda: _block(jit_mat()))
    rec = np_rec_us / jx_rec_us
    emit(f"mesh_emulation.reconstruct.m{m}", jx_rec_us,
         f"numpy_us={np_rec_us:.0f} jax_us={jx_rec_us:.0f} "
         f"speedup={rec:.1f}")

    # application semantics of the numpy oracle: rebuild + matmul per call
    _, np_app_us = timed(lambda: x @ mzi.reconstruct(prog).T, repeats=1)
    jit_apply = jax.jit(emu.apply)
    _, jx_app_us = timed(lambda: _block(jit_apply(xj)))
    app = np_app_us / jx_app_us
    emit(f"mesh_emulation.apply.m{m}.b{batch}.xla", jx_app_us,
         f"numpy_us={np_app_us:.0f} jax_us={jx_app_us:.0f} "
         f"speedup={app:.1f}")

    jit_pallas = jax.jit(lambda v: emu.apply(v, backend="pallas"))
    got, pl_app_us = timed(lambda: _block(jit_pallas(xj)))
    pl_speed = np_app_us / pl_app_us
    diff = float(jnp.max(jnp.abs(got - jit_apply(xj))))
    mode = "compiled" if _pallas_enforced() else "interpret"
    emit(f"mesh_emulation.apply.m{m}.b{batch}.pallas", pl_app_us,
         f"numpy_us={np_app_us:.0f} pallas_us={pl_app_us:.0f} "
         f"speedup={pl_speed:.1f} mode={mode} max_diff_vs_xla={diff:.2e}")
    if diff > PARITY_ATOL:
        raise RuntimeError(
            f"pallas mesh apply diverged from xla at m={m}: {diff:.2e}")
    return [rec, app], [pl_speed]


def _stacked_program(m: int, blocks: int, seed: int = 0):
    """``blocks`` random m-port programs stacked ApproxLayerProgram-style."""
    progs, meshes = [], []
    for i in range(blocks):
        rng = np.random.default_rng(seed + 7 * i + m)
        q, _ = np.linalg.qr(rng.normal(size=(m, m)))
        progs.append(mzi.givens_decompose(q))
        meshes.append(mesh.MZIMesh.compile(progs[-1]))
    return mesh._stack_meshes(meshes), progs


def bench_blocked(m: int, blocks: int, batch: int, blk_b: int = 0) -> list:
    """The block-batched path (``ApproxLayerProgram``'s stacked meshes):
    numpy per-block rebuild+matmul vs the vmapped xla scan vs ONE
    ``mesh_scan_blocks`` launch with the block axis folded into the
    kernel grid.  Returns ([xla_speedup], [pallas_speedup])."""
    st, progs = _stacked_program(m, blocks)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(batch, m)).astype(np.float32)
    xj = jnp.asarray(x)

    _, np_us = timed(
        lambda: [x @ mzi.reconstruct(p).T for p in progs], repeats=1)
    jit_xla = jax.jit(lambda v: mesh._apply_stacked(
        st, v, x_block_axis=False, backend="xla"))
    want, xla_us = timed(lambda: _block(jit_xla(xj)))
    jit_pl = jax.jit(lambda v: mesh._apply_stacked(
        st, v, x_block_axis=False, backend="pallas", blk_b=blk_b))
    got, pl_us = timed(lambda: _block(jit_pl(xj)))
    diff = float(jnp.max(jnp.abs(got - want)))
    mode = "compiled" if _pallas_enforced() else "interpret"
    xla_s, pl_s = np_us / xla_us, np_us / pl_us
    emit(f"mesh_emulation.blocked.m{m}.B{blocks}.b{batch}.xla", xla_us,
         f"numpy_us={np_us:.0f} jax_us={xla_us:.0f} speedup={xla_s:.1f}")
    emit(f"mesh_emulation.blocked.m{m}.B{blocks}.b{batch}.pallas", pl_us,
         f"numpy_us={np_us:.0f} pallas_us={pl_us:.0f} speedup={pl_s:.1f} "
         f"mode={mode} blk_b={blk_b} max_diff_vs_xla={diff:.2e}")
    if diff > PARITY_ATOL:
        raise RuntimeError(
            f"blocked pallas kernel diverged from the vmapped xla scan at "
            f"m={m} B={blocks}: {diff:.2e}")
    return [xla_s], [pl_s]


BLK_B_CANDIDATES = (32, 64, 128, 256, 512)


def sweep_blk_b(m: int = 128, blocks: int = 4, batch: int = 2048,
                candidates=BLK_B_CANDIDATES) -> int:
    """Measured ``blk_b`` selection: time the block-batched kernel at each
    candidate batch tile on one representative stacked program and report
    the fastest — the value to pass as ``--blk-b`` /
    ``PhotonicsConfig.blk_b``.  Off-TPU the kernel runs interpreted, so
    the numbers rank the tiling for the interpreter only (informational);
    re-run on TPU to tune the compiled kernel."""
    st, _ = _stacked_program(m, blocks)
    rng = np.random.default_rng(2)
    xj = jnp.asarray(rng.normal(size=(batch, m)).astype(np.float32))
    mode = "compiled" if _pallas_enforced() else "interpret"
    best, best_us = 0, float("inf")
    for blk in candidates:
        fwd = jax.jit(lambda v, b=blk: mesh._apply_stacked(
            st, v, x_block_axis=False, backend="pallas", blk_b=b))
        _, us = timed(lambda: _block(fwd(xj)))
        emit(f"mesh_emulation.blk_b_sweep.m{m}.B{blocks}.b{batch}.blk{blk}",
             us, f"blk_b={blk} mode={mode}")
        if us < best_us:
            best, best_us = blk, us
    emit(f"mesh_emulation.blk_b_sweep.best", best_us,
         f"blk_b={best} m={m} blocks={blocks} batch={batch} mode={mode}")
    return best


def bench_onn_forward(batch: int) -> dict:
    """Full programmed-ONN forward pass: numpy apply_hardware oracle vs
    both compiled emulators (xla scan, fused pallas) on the SAME program
    and the same oracle timing.  Returns {backend: speedup}."""
    params = onn.project_approx(onn.init_params(TINY, jax.random.PRNGKey(0)),
                                TINY)
    hw = onn.map_to_hardware(params, TINY)
    progs = mesh.compile_hardware(hw)
    a = np.random.default_rng(0).uniform(
        0, TINY.in_scale, size=(batch, 2)).astype(np.float32)
    aj = jnp.asarray(a)

    _, np_us = timed(onn.apply_hardware, hw, a, TINY, repeats=1)
    speedups = {}
    for backend in ("xla", "pallas"):
        fwd = jax.jit(lambda x, b=backend: mesh.apply_hardware(
            progs, x, TINY, backend=b))
        _, jx_us = timed(lambda: _block(fwd(aj)))
        speedups[backend] = np_us / jx_us
        emit(f"mesh_emulation.onn_forward.tiny.b{batch}.{backend}", jx_us,
             f"numpy_us={np_us:.0f} jax_us={jx_us:.0f} "
             f"speedup={speedups[backend]:.1f}")
    return speedups


def check_parity(widths=(2, 5, 16, 64, 128), batch: int = 32) -> float:
    """pallas(auto-interpret) == xla scan on random programs, forward and
    transpose — the cheap CI gate (f32; the <=1e-6 x64 bar lives in
    tests/test_mesh_kernel.py)."""
    worst = 0.0
    for m in widths:
        rng = np.random.default_rng(m)
        q, _ = np.linalg.qr(rng.normal(size=(m, m)))
        emu = mesh.MZIMesh.compile(mzi.givens_decompose(q))
        x = jnp.asarray(rng.normal(size=(batch, m)).astype(np.float32))
        for tr in (False, True):
            want = emu.apply(x, transpose=tr)
            got = emu.apply(x, transpose=tr, backend="pallas")
            worst = max(worst, float(jnp.max(jnp.abs(got - want))))
    emit("mesh_emulation.parity.pallas_vs_xla", 0.0,
         f"widths={list(widths)} max_diff={worst:.2e} atol={PARITY_ATOL:g}")
    if worst > PARITY_ATOL:
        raise RuntimeError(
            f"pallas mesh kernel diverged from the xla scan: {worst:.2e} "
            f"(atol {PARITY_ATOL:g})")
    return worst


def main(full: bool = False, smoke: bool = False,
         parity_only: bool = False, blk_b_sweep: bool = False) -> None:
    if blk_b_sweep:
        # measured blk_b selection is its own mode and JSON section so
        # tuning runs don't perturb the tracked perf-trajectory rows
        try:
            sweep_blk_b(batch=1024 if smoke else 2048)
        finally:
            flush_json("mesh_blk_b_sweep")
        return
    if parity_only:
        # the standalone parity sweep is its own CI step and JSON section
        # (the bench rows below carry their own in-line parity asserts, so
        # the timed runs don't repeat the sweep)
        try:
            check_parity()
        finally:
            flush_json("mesh_parity")
        return
    try:
        sizes = [(128, 1024)] if smoke else [(64, 256), (128, 2048)]
        if full:
            sizes.append((192, 2048))
        xla_speedups, pallas_speedups = [], []
        for m, b in sizes:
            xla_s, pallas_s = bench_orthogonal(m, b)
            xla_speedups.extend(xla_s)
            pallas_speedups.extend(pallas_s)
        blk_sizes = [(64, 4, 512)] if smoke else [(64, 4, 1024),
                                                  (128, 4, 2048)]
        for m, nb, b in blk_sizes:
            xla_s, pallas_s = bench_blocked(m, nb, b)
            xla_speedups.extend(xla_s)
            pallas_speedups.extend(pallas_s)
        fwd = bench_onn_forward(256)
        xla_speedups.append(fwd["xla"])
        pallas_speedups.append(fwd["pallas"])
        worst_xla = min(xla_speedups)
        worst_pallas = min(pallas_speedups)
        emit("mesh_emulation.min_speedup", 0.0,
             f"worst_xla={worst_xla:.1f} required={MIN_SPEEDUP:g} "
             f"worst_pallas={worst_pallas:.1f} "
             f"pallas_required={PALLAS_MIN_SPEEDUP:g} "
             f"pallas_enforced={_pallas_enforced()}")
        # RuntimeError (not SystemExit) so benchmarks.run's harness can
        # record the section failure and keep sweeping; the two bars are
        # enforced independently so tuning one cannot mask the other
        if worst_xla < MIN_SPEEDUP:
            raise RuntimeError(
                f"mesh emulator speedup {worst_xla:.1f}x below the "
                f"{MIN_SPEEDUP:g}x acceptance bar")
        if _pallas_enforced() and worst_pallas < PALLAS_MIN_SPEEDUP:
            raise RuntimeError(
                f"pallas mesh kernel speedup {worst_pallas:.1f}x below the "
                f"{PALLAS_MIN_SPEEDUP:g}x acceptance bar")
    finally:
        flush_json("mesh_emulation")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest sizes only (CI)")
    ap.add_argument("--full", action="store_true",
                    help="add the 192-port mesh")
    ap.add_argument("--parity", action="store_true",
                    help="only the pallas-vs-xla parity gate (fast)")
    ap.add_argument("--blk-b-sweep", action="store_true",
                    help="measured blk_b selection: time the block-batched "
                         "kernel at each candidate batch tile")
    args = ap.parse_args()
    try:
        main(full=args.full, smoke=args.smoke, parity_only=args.parity,
             blk_b_sweep=args.blk_b_sweep)
    except RuntimeError as e:
        raise SystemExit(str(e))
